//! End-to-end training integration: every algorithm must run rounds
//! against the real PJRT runtime, learn above chance on a short horizon,
//! and produce communication-ledger numbers consistent with its Table-1
//! capability row.
//!
//! Requires `make artifacts` (skips gracefully otherwise). PJRT handles
//! are not Send/Sync, so each #[test] builds its own Lab; checks are
//! grouped to amortize the multi-second artifact compilation.

use pfed1bs::algorithms;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::{evaluate, Coordinator};
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;
use pfed1bs::sketch::bitpack::packed_bytes;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn short_cfg(alg: &str) -> RunConfig {
    let mut cfg = RunConfig::preset(DatasetName::Mnist);
    cfg.algorithm = alg.to_string();
    cfg.rounds = 4;
    cfg.local_steps = 5;
    cfg.eval_every = 3;
    cfg.seed = 41;
    cfg
}

#[test]
fn all_algorithms_learn_and_ledger_matches_capabilities() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    // (a) every algorithm learns above its chance floor in 4 rounds
    let mut results = std::collections::HashMap::new();
    for alg in ["pfed1bs", "local", "fedavg", "obcsaa", "zsignfed", "eden", "fedbat", "obda"] {
        let result = lab.run(short_cfg(alg)).unwrap_or_else(|e| panic!("{alg}: {e:#}"));
        let floor = match alg {
            "pfed1bs" | "local" => 0.60,
            // the stochastic-sign estimators start slowly (zSignFed
            // reaches ~0.83 at the 100-round preset; see EXPERIMENTS.md)
            "zsignfed" | "fedbat" => 0.10,
            _ => 0.15,
        };
        assert!(
            result.final_accuracy > floor,
            "{alg}: accuracy {:.3} <= {floor}",
            result.final_accuracy
        );
        assert_eq!(result.history.records.len(), 4);
        results.insert(alg, result);
    }

    // (b) the paper's central short-horizon claim
    assert!(
        results["pfed1bs"].final_accuracy > results["obda"].final_accuracy,
        "pfed1bs must beat the one-bit global baseline under label skew"
    );

    // (c) measured costs ordered per the capability matrix
    let p = &results["pfed1bs"];
    let o = &results["obda"];
    let f = &results["fedavg"];
    assert!(p.mean_round_mb < o.mean_round_mb / 4.0);
    assert!(o.mean_round_mb < f.mean_round_mb / 8.0);
    assert!(results["local"].mean_round_mb == 0.0);

    // (d) pFed1BS bytes exactly = S·(uplink m-bit frame) + S·(downlink
    // m-bit frame); round 0 skips the downlink (v⁰ = 0)
    let cfg = short_cfg("pfed1bs");
    let m = lab.executables("mlp784").unwrap().geom.m;
    let per_msg = (5 + m.div_ceil(64) * 8) as u64;
    let last = p.history.records.last().unwrap().bytes;
    assert_eq!(last.total(), 2 * cfg.participating as u64 * per_msg);
    let first = p.history.records.first().unwrap().bytes;
    assert_eq!(first.total(), cfg.participating as u64 * per_msg);

    // (e) FedAvg bytes exactly = 2 directions × S × dense frame
    let n = lab.executables("mlp784").unwrap().geom.n;
    let dense_msg = (5 + 4 * n) as u64;
    let f_last = f.history.records.last().unwrap().bytes;
    assert_eq!(f_last.total(), 2 * cfg.participating as u64 * dense_msg);
}

#[test]
fn determinism_and_dense_projection_ablation() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    // same seed ⇒ identical trajectory
    let a = lab.run(short_cfg("pfed1bs")).unwrap();
    let b = lab.run(short_cfg("pfed1bs")).unwrap();
    assert_eq!(a.final_accuracy, b.final_accuracy);
    let la: Vec<f64> = a.history.records.iter().map(|r| r.train_loss).collect();
    let lb: Vec<f64> = b.history.records.iter().map(|r| r.train_loss).collect();
    assert_eq!(la, lb, "training trajectory must be seed-deterministic");

    // Appendix Fig. 3: dense Gaussian projection tracks the FHT. The
    // dense apply is O(mn) (that is the paper's whole point), so this
    // check runs a minimal federation: 3 clients, 2 rounds, 2 steps.
    let mut cfg_f = short_cfg("pfed1bs");
    cfg_f.clients = 3;
    cfg_f.participating = 3;
    cfg_f.rounds = 2;
    cfg_f.local_steps = 2;
    cfg_f.eval_every = 1;
    let mut cfg_d = cfg_f.clone();
    cfg_d.projection = pfed1bs::config::ProjectionKind::DenseGaussian;
    let f = lab.run(cfg_f).unwrap();
    let d = lab.run(cfg_d).unwrap();
    assert!(
        (f.final_accuracy - d.final_accuracy).abs() < 0.15,
        "fht {:.3} vs dense {:.3}",
        f.final_accuracy,
        d.final_accuracy
    );
}

#[test]
fn per_round_byte_totals_match_known_good_values() {
    // Byte metering must be invariant under the phased-protocol refactor:
    // these are the exact pre-refactor per-round uplink/downlink totals,
    // derived from the wire-frame sizes each algorithm transmits.
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let geom = lab.executables("mlp784").unwrap().geom;
    let (n, m) = (geom.n, geom.m);
    // EDEN rotates with its own SRHT realization over n, so its uplink
    // length is n padded to the next power of two
    let npad = n.next_power_of_two();
    let dense = |len: usize| (5 + 4 * len) as u64;
    let signs = |len: usize| (5 + packed_bytes(len)) as u64;
    let scaled = |len: usize| (9 + packed_bytes(len)) as u64;

    // (alg, uplink frame, downlink frame, downlink skipped at round 0)
    let expectations: [(&str, u64, u64, bool); 8] = [
        ("pfed1bs", signs(m), signs(m), true),
        ("fedavg", dense(n), dense(n), false),
        ("obda", scaled(n), scaled(n), false),
        ("obcsaa", scaled(m), dense(n), false),
        ("zsignfed", scaled(n), dense(n), false),
        ("eden", scaled(npad), dense(n), false),
        ("fedbat", scaled(n), dense(n), false),
        ("local", 0, 0, false),
    ];
    for (alg, up_frame, down_frame, skip_r0) in expectations {
        let mut cfg = short_cfg(alg);
        cfg.rounds = 2;
        let s = cfg.participating as u64;
        let result = lab.run(cfg).unwrap_or_else(|e| panic!("{alg}: {e:#}"));
        for (t, rec) in result.history.records.iter().enumerate() {
            assert_eq!(rec.bytes.uplink, s * up_frame, "{alg} round {t} uplink");
            let expect_down = if t == 0 && skip_r0 { 0 } else { s * down_frame };
            assert_eq!(rec.bytes.downlink, expect_down, "{alg} round {t} downlink");
            let expect_up_msgs = if up_frame == 0 { 0 } else { s as u32 };
            let expect_down_msgs = if expect_down == 0 { 0 } else { s as u32 };
            assert_eq!(rec.bytes.uplink_msgs, expect_up_msgs, "{alg} round {t} up msgs");
            assert_eq!(rec.bytes.downlink_msgs, expect_down_msgs, "{alg} round {t} down msgs");
            // default scenario knobs: every barrier round delivers the
            // full cohort through the event engine, nobody is cut
            assert_eq!(rec.delivered as u64, s, "{alg} round {t} delivered");
            assert_eq!(rec.stragglers_cut, 0, "{alg} round {t} stragglers");
        }
    }
}

/// Golden-trace regression: a seeded 5-round pFed1BS run has its
/// per-round losses (exact f64 bits) and final consensus bit-vector
/// (exact packed words) pinned to `tests/golden/pfed1bs_trace.golden`.
/// Once a trace is committed, any later run must reproduce it
/// bit-for-bit — so representation changes (e.g. f32 sign lanes →
/// packed `SignVec`) are machine-checked for trajectory identity, not
/// desk-checked. Within one run the test also cross-checks 1-thread vs
/// 4-thread execution, which must be bit-identical regardless of the
/// golden.
///
/// Recording is explicit opt-in only: `PFED1BS_UPDATE_GOLDEN=1 cargo
/// test --release golden_trace` writes the file (use the tier-1
/// release profile); **commit it** to arm the comparison. When the
/// golden is absent and the flag unset, the test does NOT record — it
/// warns loudly and still enforces the thread-count identity, so a
/// debug-profile run can never plant a golden that a release run then
/// compares against. (The no-artifacts complement that always compares
/// against hand-computed words is
/// `golden_protocol_vote_and_wire_bytes_without_runtime` in
/// prop_coordinator.rs.)
#[test]
fn golden_trace_pfed1bs_losses_and_consensus_bits() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut traces: Vec<String> = Vec::new();
    for threads in [1usize, 4] {
        let mut cfg = short_cfg("pfed1bs");
        cfg.rounds = 5;
        cfg.seed = 1234;
        cfg.client_threads = threads;
        let model = lab.model_for(&cfg).unwrap();
        let mut alg = algorithms::build("pfed1bs").unwrap();
        let mut coord = Coordinator::new(cfg, &model);
        let result = coord.run(alg.as_mut()).unwrap();
        let mut lines: Vec<String> = result
            .history
            .records
            .iter()
            .map(|r| format!("round {} loss_bits {:016x}", r.round, r.train_loss.to_bits()))
            .collect();
        let v = alg.consensus_packed().expect("pfed1bs exposes its packed consensus");
        let hex: String = v.words().iter().map(|w| format!("{w:016x}")).collect();
        lines.push(format!("consensus m {} words {hex}", v.m()));
        traces.push(lines.join("\n") + "\n");
    }
    assert_eq!(
        traces[0], traces[1],
        "1-thread and 4-thread traces must be bit-identical"
    );

    let path = std::path::Path::new("tests/golden/pfed1bs_trace.golden");
    if std::env::var("PFED1BS_UPDATE_GOLDEN").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, &traces[0]).unwrap();
        eprintln!(
            "recorded golden trace to {} — COMMIT THIS FILE to arm the \
             bit-identity comparison",
            path.display()
        );
    } else if path.exists() {
        let want = std::fs::read_to_string(path).expect("read golden trace");
        assert_eq!(
            traces[0], want,
            "pFed1BS trajectory diverged from the committed golden trace: \
             losses and consensus bits must be bit-identical across \
             refactors (PFED1BS_UPDATE_GOLDEN=1 re-records after an \
             intentional semantic change)"
        );
    } else {
        eprintln!(
            "WARNING: no golden trace committed at {} — only the \
             thread-identity cross-check ran; record one with \
             PFED1BS_UPDATE_GOLDEN=1 cargo test --release golden_trace \
             and commit it",
            path.display()
        );
    }
}

#[test]
fn parallel_client_phase_is_bit_identical_to_serial() {
    // the data-parallel client phase must produce exactly the results of
    // a forced single-thread round: same losses, bytes, and model state
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    for alg_name in ["pfed1bs", "fedavg"] {
        let mut snaps = Vec::new();
        for threads in [1usize, 4] {
            let mut cfg = short_cfg(alg_name);
            cfg.rounds = 3;
            cfg.client_threads = threads;
            let model = lab.model_for(&cfg).unwrap();
            let mut alg = algorithms::build(alg_name).unwrap();
            let mut coord = Coordinator::new(cfg, &model);
            let result = coord.run(alg.as_mut()).unwrap();
            let losses: Vec<f64> =
                result.history.records.iter().map(|r| r.train_loss).collect();
            let bytes: Vec<_> = result.history.records.iter().map(|r| r.bytes).collect();
            snaps.push((losses, bytes, result.final_accuracy, alg.snapshot()));
        }
        assert_eq!(snaps[0].0, snaps[1].0, "{alg_name}: losses differ across thread counts");
        assert_eq!(snaps[0].1, snaps[1].1, "{alg_name}: byte counts differ");
        assert_eq!(snaps[0].2, snaps[1].2, "{alg_name}: final accuracy differs");
        assert_eq!(snaps[0].3, snaps[1].3, "{alg_name}: model state differs");
    }
}

/// Client-lifecycle scenario: over-selection + dropouts + heterogeneous
/// latency + a deadline. Pins the byte/bookkeeping contract: every
/// computed uplink is metered whether or not the deadline cut it, the
/// downlink still reaches the whole over-selected cohort (the server
/// cannot know who dropped), and the delivered set the CSV reports is
/// exactly what the ledger's message counts say was aggregated.
#[test]
fn scenario_rounds_meter_stragglers_and_bound_delivery() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut cfg = short_cfg("pfed1bs");
    cfg.rounds = 4;
    cfg.participating = 12;
    cfg.over_select = 4; // cohort of 16
    cfg.dropout_prob = 0.25;
    cfg.latency = pfed1bs::comm::LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 50.0 };
    cfg.deadline_ms = 25.0;
    cfg.validate().unwrap();
    let m = lab.executables("mlp784").unwrap().geom.m;
    let per_msg = (5 + m.div_ceil(64) * 8) as u64;

    let model = lab.model_for(&cfg).unwrap();
    let mut alg = algorithms::build("pfed1bs").unwrap();
    let mut coord = Coordinator::new(cfg.clone(), &model);
    let result = coord.run(alg.as_mut()).unwrap();

    let mut any_lifecycle_event = false;
    for (t, rec) in result.history.records.iter().enumerate() {
        // every computed uplink was transported: delivered + cut
        let sent = rec.delivered + rec.stragglers_cut;
        assert_eq!(rec.bytes.uplink_msgs as usize, sent, "round {t} uplink msgs");
        assert_eq!(rec.bytes.uplink, sent as u64 * per_msg, "round {t} uplink bytes");
        // the broadcast reaches the whole over-selected cohort, dropouts
        // included — the server cannot know who is gone — except round 0
        // (pFed1BS skips the downlink while v⁰ = 0)
        let expect_down_msgs = if t == 0 { 0u32 } else { 16 };
        assert_eq!(rec.bytes.downlink_msgs, expect_down_msgs, "round {t} downlink msgs");
        assert_eq!(
            rec.bytes.downlink,
            expect_down_msgs as u64 * per_msg,
            "round {t} downlink bytes"
        );
        assert!(rec.delivered <= 12, "round {t} delivered past the target");
        any_lifecycle_event |= rec.stragglers_cut > 0 || rec.delivered < 12;
    }
    assert!(
        any_lifecycle_event,
        "scenario knobs produced no dropout/straggler in 4 rounds"
    );
    // the run still learns above chance despite losing ~half the fleet
    assert!(
        result.final_accuracy > 0.2,
        "accuracy {:.3} collapsed under the flaky-fleet scenario",
        result.final_accuracy
    );
}

/// Quorum + churn scenario (DESIGN.md §13), mirroring the flaky-fleet
/// test above: rounds close at a 10-of-16 quorum, the in-time tail is
/// buffered one round stale instead of cut, and availability waves churn
/// clients out for whole periods. Pins the new bookkeeping contract —
/// every computed uplink is metered whether it was absorbed, buffered,
/// or cut; a round's `stale_weight` is exactly the carried mass share of
/// the previous round's buffered tail — and keeps the accuracy floor:
/// staleness-decayed late sketches must help, not poison, the vote.
#[test]
fn quorum_churn_rounds_buffer_lates_and_still_learn() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut cfg = short_cfg("pfed1bs");
    cfg.rounds = 4;
    cfg.participating = 12;
    cfg.over_select = 4; // cohort of 16
    cfg.quorum = 10;
    cfg.max_staleness = 1;
    cfg.staleness_decay = 0.5;
    cfg.churn_prob = 0.25;
    cfg.churn_period = 2;
    cfg.latency = pfed1bs::comm::LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 30.0 };
    cfg.validate().unwrap();
    let m = lab.executables("mlp784").unwrap().geom.m;
    let per_msg = (5 + m.div_ceil(64) * 8) as u64;

    let model = lab.model_for(&cfg).unwrap();
    let mut alg = algorithms::build("pfed1bs").unwrap();
    let mut coord = Coordinator::new(cfg.clone(), &model);
    let result = coord.run(alg.as_mut()).unwrap();

    let (mut any_quorum_close, mut any_buffered, mut any_stale, mut any_churn) =
        (false, false, false, false);
    let mut prev_buffered = 0usize;
    for (t, rec) in result.history.records.iter().enumerate() {
        // every computed uplink was transported: absorbed + buffered + cut
        let sent = rec.delivered + rec.buffered_late + rec.stragglers_cut;
        assert_eq!(rec.bytes.uplink_msgs as usize, sent, "round {t} uplink msgs");
        assert_eq!(rec.bytes.uplink, sent as u64 * per_msg, "round {t} uplink bytes");
        // the broadcast still reaches the whole cohort, churned clients
        // included (the server cannot know who left) — except round 0
        let expect_down_msgs = if t == 0 { 0u32 } else { 16 };
        assert_eq!(rec.bytes.downlink_msgs, expect_down_msgs, "round {t} downlink msgs");
        // the quorum, not the target count, bounds fresh deliveries
        assert!(rec.delivered <= 10, "round {t}: delivered past the quorum");
        // a round's stale share is carried mass / norm mass: a proper
        // fraction, and nonzero exactly when round t-1 buffered a tail
        assert!(
            (0.0..1.0).contains(&rec.stale_weight),
            "round {t}: stale_weight {} out of range",
            rec.stale_weight
        );
        if prev_buffered > 0 && rec.delivered > 0 {
            assert!(
                rec.stale_weight > 0.0,
                "round {t}: buffered tail from round {} never materialized",
                t - 1
            );
        }
        any_quorum_close |= rec.quorum_closed;
        any_buffered |= rec.buffered_late > 0;
        any_stale |= rec.stale_weight > 0.0;
        any_churn |= rec.delivered + rec.buffered_late + rec.stragglers_cut < 16;
        prev_buffered = rec.buffered_late;
    }
    assert!(any_quorum_close, "a 10-of-16 quorum never closed a round early");
    assert!(any_buffered, "no in-time tail was ever buffered");
    assert!(any_stale, "no buffered tail ever joined a later tally");
    assert!(any_churn, "0.25 churn over 2 waves removed nobody");
    // the run still learns above chance with a third of each round's
    // sketches arriving a round late at half weight
    assert!(
        result.final_accuracy > 0.2,
        "accuracy {:.3} collapsed under the quorum/churn scenario",
        result.final_accuracy
    );
}

/// Tentpole acceptance at full engine level: a real training run under
/// `edge:4` must reproduce the flat run's consensus and personalized
/// models bit-for-bit (exact tally kinds — DESIGN.md §11), keep the
/// client-tier byte metering byte-identical, and additionally meter the
/// edge tier (root→edge fan-out + edge→root merge frames).
#[test]
fn edge_topology_run_matches_flat_bit_for_bit_and_meters_the_edge_tier() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let m = lab.executables("mlp784").unwrap().geom.m;
    let per_msg = (5 + m.div_ceil(64) * 8) as u64;
    let tally_frame = (33 + 16 * m) as u64;

    let mut snaps = Vec::new();
    for topology in ["flat", "edge:4"] {
        let mut cfg = short_cfg("pfed1bs");
        cfg.rounds = 3;
        cfg.apply_pairs([("topology", topology)].into_iter()).unwrap();
        cfg.validate().unwrap();
        let model = lab.model_for(&cfg).unwrap();
        let mut alg = algorithms::build("pfed1bs").unwrap();
        let mut coord = Coordinator::new(cfg.clone(), &model);
        let result = coord.run(alg.as_mut()).unwrap();

        for (t, rec) in result.history.records.iter().enumerate() {
            // client tier: byte-identical to the flat assertions of
            // `per_round_byte_totals_match_known_good_values`
            let s = cfg.participating as u64;
            assert_eq!(rec.bytes.uplink, s * per_msg, "{topology} round {t} uplink");
            let down = if t == 0 { 0 } else { s * per_msg };
            assert_eq!(rec.bytes.downlink, down, "{topology} round {t} downlink");
            match topology {
                "flat" => {
                    assert_eq!(rec.edges, 0);
                    assert_eq!((rec.bytes.edge_up, rec.bytes.edge_down), (0, 0));
                }
                _ => {
                    assert_eq!(rec.edges, 4);
                    // 20 clients cover all 4 edges: 4 merge frames per
                    // round, 4 fan-out copies whenever v broadcasts
                    assert_eq!(rec.bytes.edge_up_msgs, 4, "{topology} round {t}");
                    assert_eq!(rec.bytes.edge_up, 4 * tally_frame);
                    let fan = if t == 0 { 0 } else { 4 };
                    assert_eq!(rec.bytes.edge_down_msgs, fan);
                    assert_eq!(rec.bytes.edge_down, fan as u64 * per_msg);
                }
            }
        }
        snaps.push((
            alg.snapshot(),
            alg.consensus_packed().unwrap().words().to_vec(),
            result.final_accuracy,
        ));
    }
    assert_eq!(
        snaps[0].1, snaps[1].1,
        "edge:4 consensus words must equal the flat server's bit-for-bit"
    );
    assert_eq!(snaps[0].0, snaps[1].0, "personalized models diverged under edge:4");
    assert_eq!(snaps[0].2, snaps[1].2);
}

/// Checkpoint satellite: edge assignment is derived, not persisted — a
/// checkpoint taken mid-run under `edge:4` must carry exactly the flat
/// run's state (plus the informational edge count), and resuming from
/// either checkpoint must replay the remaining rounds identically.
#[test]
fn checkpoint_resume_replays_identically_flat_vs_edge4() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let dir = std::env::temp_dir().join(format!("pfed1bs_topo_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut ckpts = Vec::new();
    for topology in ["flat", "edge:4"] {
        let mut cfg = short_cfg("pfed1bs");
        cfg.rounds = 2;
        cfg.apply_pairs([("topology", topology)].into_iter()).unwrap();
        let path = dir.join(format!("{}.ckpt", topology.replace(':', "_")));
        let model = lab.model_for(&cfg).unwrap();
        let mut alg = algorithms::build("pfed1bs").unwrap();
        let mut coord = Coordinator::new(cfg, &model);
        coord.checkpoint = Some((path.to_str().unwrap().to_string(), 2));
        coord.run(alg.as_mut()).unwrap();
        ckpts.push(pfed1bs::coordinator::Checkpoint::load(&path).unwrap());
    }
    let (flat, edged) = (&ckpts[0], &ckpts[1]);
    assert_eq!(flat.edges, 0, "flat checkpoint records no edge tier");
    assert_eq!(edged.edges, 4, "edge:4 checkpoint records its edge count");
    assert_eq!(flat.round, edged.round);
    assert_eq!(
        flat.consensus, edged.consensus,
        "topology leaked into checkpointed consensus"
    );
    assert_eq!(flat.models, edged.models, "topology leaked into checkpointed models");

    // resume both and replay two more rounds (driven through the public
    // round API — `Coordinator::run` would re-init and wipe the
    // restored state) — trajectories must match bit-for-bit
    let mut finals = Vec::new();
    for (topology, ckpt) in [("flat", flat), ("edge:4", edged)] {
        let mut cfg = short_cfg("pfed1bs");
        cfg.rounds = 2;
        cfg.apply_pairs([("topology", topology)].into_iter()).unwrap();
        let model = lab.model_for(&cfg).unwrap();
        let mut alg = algorithms::build("pfed1bs").unwrap();
        let mut coord = Coordinator::new(cfg, &model);
        coord.init_algorithm(alg.as_mut()).unwrap();
        alg.restore(ckpt.models.clone(), ckpt.consensus.clone()).unwrap();
        let selected: Vec<usize> = (0..coord.cfg.participating).collect();
        let weights = {
            let raw: Vec<f32> = selected.iter().map(|&k| coord.data.weights[k]).collect();
            let total: f32 = raw.iter().sum();
            raw.into_iter().map(|w| w / total).collect::<Vec<f32>>()
        };
        for t in ckpt.round as usize..ckpt.round as usize + 2 {
            coord.run_round(alg.as_mut(), t, &selected, &weights).unwrap();
            coord.net.end_round();
        }
        // model + consensus state is exact under both topologies; the
        // f64 loss mean may reassociate across shard merges, so it is
        // deliberately not part of this bit-equality
        finals.push(alg.snapshot());
    }
    assert_eq!(finals[0], finals[1], "resumed replay diverged between topologies");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn noisy_uplink_and_partial_participation() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    // 5% of sketch bits flip in transit: the 20-client majority vote must
    // absorb it
    let cfg = short_cfg("pfed1bs");
    let model = lab.model_for(&cfg).unwrap();
    let mut alg = algorithms::build("pfed1bs").unwrap();
    let mut coord = Coordinator::new(cfg, &model);
    coord.net.bit_flip_prob = 0.05;
    let result = coord.run(alg.as_mut()).unwrap();
    assert!(
        result.final_accuracy > 0.6,
        "accuracy {:.3} under 5% bit flips",
        result.final_accuracy
    );
    let ev = evaluate(coord.model, &coord.data, alg.as_ref()).unwrap();
    assert!((ev.accuracy - result.final_accuracy).abs() < 1e-9);

    // S=5 of K=20 (Appendix Fig. 1 setting) still learns
    let mut cfg = short_cfg("pfed1bs");
    cfg.participating = 5;
    cfg.rounds = 6;
    let result = lab.run(cfg).unwrap();
    assert!(result.final_accuracy > 0.5, "acc {:.3}", result.final_accuracy);
}
