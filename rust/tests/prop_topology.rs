//! Property tests for the hierarchical (client → edge → root)
//! aggregation topology (DESIGN.md §11). No PJRT runtime needed: these
//! drive the aggregation layer with synthetic client outputs.
//!
//! THE topology theorem, pinned here: for every exact `AggKind`
//! (Vote / ScaledVote / SignSum / SketchSum), splitting the delivered
//! uplinks across E edge shards — under an ARBITRARY client→edge
//! assignment, E ∈ 1..8, absorbed through the engine's own
//! `par_map_consume` at ≥2 thread counts — and merging the shards in
//! canonical edge order is bit-identical to the flat server absorbing
//! the same uplinks in arrival order. The edge→root wire frames
//! (`Payload::TallyFrame`) carry the shards exactly: folding decoded
//! frames reproduces the same bits.

use pfed1bs::algorithms::{
    AggKind, Algorithm, ClientOutput, ClientStats, RoundAggregator, ServerCtx, Uplink,
};
use pfed1bs::comm::{decode, encode, Payload, SimNetwork};
use pfed1bs::config::{RunConfig, Topology};
use pfed1bs::coordinator::parallel::par_map_consume;
use pfed1bs::data::DatasetName;
use pfed1bs::sketch::bitpack::{ScalarTally, SignVec, VoteAccumulator};
use pfed1bs::sketch::{Projection, SrhtOperator};
use pfed1bs::util::proptest::check;
use pfed1bs::util::rng::Rng;

/// The four exact aggregation kinds under test.
#[derive(Clone, Copy, Debug)]
enum Kind {
    Vote,
    ScaledVote,
    SignSum,
    SketchSum,
}

const KINDS: [Kind; 4] = [Kind::Vote, Kind::ScaledVote, Kind::SignSum, Kind::SketchSum];

fn fresh(kind: Kind, m: usize) -> RoundAggregator {
    RoundAggregator::new(match kind {
        Kind::Vote => AggKind::Vote(VoteAccumulator::new(m)),
        Kind::ScaledVote => AggKind::ScaledVote {
            tally: VoteAccumulator::new(m),
            scale: ScalarTally::new(),
        },
        Kind::SignSum => AggKind::SignSum(VoteAccumulator::new(m)),
        Kind::SketchSum => AggKind::SketchSum {
            tally: VoteAccumulator::new(m),
            norm: ScalarTally::new(),
        },
    })
}

fn rand_output(kind: Kind, rng: &mut Rng, client: usize, m: usize) -> ClientOutput {
    let signs = SignVec::from_fn(m, |_| rng.f32() < 0.5);
    let payload = match kind {
        Kind::Vote => Payload::Signs(signs),
        _ => Payload::ScaledSigns { signs, scale: rng.f32() * 3.0 + 0.01 },
    };
    ClientOutput {
        client,
        uplink: Some(Uplink::new(0, payload)),
        state: Some(vec![client as f32]),
        stats: ClientStats { loss: rng.f64() * 5.0 },
    }
}

/// The bit-level fingerprint of an aggregator's server-state content:
/// every tally quantum, the scalar companion, and the absorbed count.
fn fingerprint(agg: RoundAggregator) -> (Vec<i128>, i128, usize) {
    let (kind, _, absorbed, _) = agg.into_parts();
    match kind {
        AggKind::Vote(t) | AggKind::SignSum(t) => (t.quanta().to_vec(), 0, absorbed),
        AggKind::ScaledVote { tally, scale } => {
            (tally.quanta().to_vec(), scale.quanta(), absorbed)
        }
        AggKind::SketchSum { tally, norm } => {
            (tally.quanta().to_vec(), norm.quanta(), absorbed)
        }
        _ => panic!("unexpected kind"),
    }
}

#[test]
fn prop_edge_merge_bit_identical_to_flat_for_all_exact_kinds() {
    check("topology_bit_identity", 40, |rng| {
        let k = rng.below(24) + 1;
        let m = rng.below(300) + 1;
        for kind in KINDS {
            let outputs: Vec<ClientOutput> =
                (0..k).map(|c| rand_output(kind, rng, c, m)).collect();
            let mut weights: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
            let total: f32 = weights.iter().sum();
            weights.iter_mut().for_each(|w| *w /= total);

            // the flat oracle: one aggregator, arrival order
            let mut flat = fresh(kind, m);
            for (out, &w) in outputs.iter().zip(&weights) {
                flat.absorb(out.clone(), w).map_err(|e| e.to_string())?;
            }
            let want = fingerprint(flat);

            for edges in 1..=8usize {
                // ARBITRARY assignment — not just k mod E
                let assign: Vec<usize> = (0..k).map(|_| rng.below(edges)).collect();
                let mut shards: Vec<RoundAggregator> =
                    (0..edges).map(|_| fresh(kind, m)).collect();
                for (i, (out, &w)) in outputs.iter().zip(&weights).enumerate() {
                    shards[assign[i]]
                        .absorb(out.clone(), w)
                        .map_err(|e| e.to_string())?;
                }
                // canonical edge-order merge into the root
                let mut it = shards.into_iter();
                let mut root = it.next().unwrap();
                for s in it {
                    root.merge(s).map_err(|e| e.to_string())?;
                }
                if fingerprint(root) != want {
                    return Err(format!(
                        "{kind:?}: E={edges} merged tally != flat tally (K={k}, m={m})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_edge_shards_through_par_map_consume_match_flat_at_any_thread_count() {
    // the engine's own absorb shape: workers compute, the caller thread
    // folds each arrival into its edge's shard in a scrambled arrival
    // order — across thread counts 1 and 4 the merged result must equal
    // the flat oracle bit-for-bit
    check("topology_threaded_absorb", 15, |rng| {
        let k = rng.below(20) + 2;
        let m = rng.below(200) + 1;
        let edges = rng.below(8) + 1;
        for kind in KINDS {
            let outputs: Vec<ClientOutput> =
                (0..k).map(|c| rand_output(kind, rng, c, m)).collect();
            let weights: Vec<f32> = vec![1.0 / k as f32; k];
            let mut order: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut order);
            let assign: Vec<usize> = (0..k).map(|_| rng.below(edges)).collect();

            // flat oracle in the same scrambled arrival order
            let mut flat = fresh(kind, m);
            for &i in &order {
                flat.absorb(outputs[i].clone(), weights[i]).map_err(|e| e.to_string())?;
            }
            let want = fingerprint(flat);

            for threads in [1usize, 4] {
                let mut shards: Vec<RoundAggregator> =
                    (0..edges).map(|_| fresh(kind, m)).collect();
                par_map_consume(
                    outputs.clone(),
                    threads,
                    &order,
                    |_, out: ClientOutput| out, // "compute" = hand back the uplink
                    |i, out| -> Result<(), String> {
                        shards[assign[i]]
                            .absorb(out, weights[i])
                            .map_err(|e| e.to_string())
                    },
                )?;
                let mut it = shards.into_iter();
                let mut root = it.next().unwrap();
                for s in it {
                    root.merge(s).map_err(|e| e.to_string())?;
                }
                if fingerprint(root) != want {
                    return Err(format!(
                        "{kind:?}: threads={threads}, E={edges}: engine-shaped \
                         edge fold != flat oracle"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tally_frames_carry_edge_shards_exactly() {
    // the edge→root wire path: every shard is encoded to its
    // Payload::TallyFrame, shipped through the (clean, metered) edge
    // tier, decoded, and folded with absorb_frame — the root must land
    // on the identical bits as the in-memory merge, for every exact kind
    check("topology_wire_frames", 20, |rng| {
        let k = rng.below(16) + 1;
        let m = rng.below(200) + 1;
        let edges = rng.below(6) + 1;
        for kind in KINDS {
            let outputs: Vec<ClientOutput> =
                (0..k).map(|c| rand_output(kind, rng, c, m)).collect();
            let weights: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
            let assign: Vec<usize> = (0..k).map(|_| rng.below(edges)).collect();
            let mut shards: Vec<RoundAggregator> =
                (0..edges).map(|_| fresh(kind, m)).collect();
            for (i, (out, &w)) in outputs.iter().zip(&weights).enumerate() {
                shards[assign[i]].absorb(out.clone(), w).map_err(|e| e.to_string())?;
            }

            let mut net = SimNetwork::new(rng.next_u64());
            let mut via_wire = fresh(kind, m);
            let mut frames = 0u32;
            for (e, shard) in shards.iter().enumerate() {
                let frame = shard.merge_payload().expect("exact kinds always report");
                // codec round trip must be exact for arbitrary quanta
                if decode(&encode(&frame)).map_err(|e| e.to_string())? != frame {
                    return Err("tally frame codec round trip".into());
                }
                let delivered = net.edge_uplink(e, &frame).map_err(|e| e.to_string())?;
                via_wire.absorb_frame(delivered).map_err(|e| e.to_string())?;
                frames += 1;
            }
            let bytes = net.end_round();
            if bytes.edge_up_msgs != frames || bytes.edge_up == 0 {
                return Err("edge tier metering missed merge frames".into());
            }

            let mut it = shards.into_iter();
            let mut in_memory = it.next().unwrap();
            for s in it {
                in_memory.merge(s).map_err(|e| e.to_string())?;
            }
            let (wq, ws, wa) = fingerprint(via_wire);
            let (mq, ms, ma) = fingerprint(in_memory);
            if (wq, ws, wa) != (mq, ms, ma) {
                return Err(format!("{kind:?}: wire-merged root != in-memory root"));
            }
        }
        Ok(())
    });
}

/// Protocol-level pFed1BS check, no artifacts: the hand-computed golden
/// consensus of `golden_protocol_vote_and_wire_bytes_without_runtime`
/// (prop_coordinator.rs) must also fall out of an edge-sharded server,
/// for every assignment of the three clients to two edges.
#[test]
fn golden_protocol_vote_survives_every_two_edge_sharding() {
    let m = 130;
    let n = 16;
    let z0 = SignVec::from_fn(m, |i| i % 2 == 0);
    let z1 = SignVec::from_fn(m, |i| i % 3 == 0);
    let z2 = SignVec::from_fn(m, |_| true);
    let weights = [0.5f32, 0.25, 0.25];
    let want = SignVec::from_fn(m, |i| i % 2 == 0 || i % 3 == 0);

    let cfg = RunConfig::preset(DatasetName::Mnist);
    let projection = Projection::Srht(SrhtOperator::from_seed(1, n, n));
    let ctx = ServerCtx { cfg: &cfg, projection: &projection };

    // all 2^3 assignments of three clients to two edges
    for mask in 0..8u32 {
        let mut alg = pfed1bs::algorithms::pfed1bs::PFed1BS::with_state(
            vec![vec![0.0f32; n]; 3],
            vec![1.0f32; m],
        );
        let mut shards = [alg.begin_aggregate(1), alg.begin_aggregate(1)];
        for (c, z) in [&z0, &z1, &z2].into_iter().enumerate() {
            let out = ClientOutput {
                client: c,
                uplink: Some(Uplink::new(1, Payload::Signs(z.clone()))),
                state: None,
                stats: ClientStats::default(),
            };
            shards[(mask >> c & 1) as usize].absorb(out, weights[c]).unwrap();
        }
        let [mut root, other] = shards;
        root.merge(other).unwrap();
        alg.finish_aggregate(1, root, &ctx).unwrap();
        assert_eq!(
            alg.consensus_packed().unwrap(),
            &want,
            "sharding mask {mask:03b} changed the analytic consensus"
        );
    }
}

/// The engine plan's derived assignment and the failed-edge demotion
/// compose: a plan with a failed edge delivers no arrival from that
/// edge, and the surviving weights stay a probability vector.
#[test]
fn plan_with_edge_outages_keeps_delivered_weights_normalized() {
    check("topology_plan_outages", 20, |rng| {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.clients = rng.below(30) + 4;
        cfg.participating = rng.below(cfg.clients) + 1;
        cfg.topology = Topology::Edge { edges: rng.below(8) + 1 };
        cfg.edge_dropout_prob = rng.f64() * 0.6;
        if cfg.edge_dropout_prob == 0.0 {
            cfg.edge_dropout_prob = 0.3;
        }
        cfg.validate().map_err(|e| e.to_string())?;
        let weights: Vec<f32> = {
            let raw: Vec<f32> = (0..cfg.clients).map(|_| rng.f32() + 0.01).collect();
            let t: f32 = raw.iter().sum();
            raw.into_iter().map(|w| w / t).collect()
        };
        let mut net = SimNetwork::new(rng.next_u64());
        let mut prng = Rng::new(rng.next_u64());
        for t in 0..4 {
            let plan =
                pfed1bs::coordinator::plan_round(t, &cfg, &weights, &mut net, &mut prng);
            for a in &plan.arrivals {
                if plan.failed_edges.contains(&cfg.topology.edge_of(a.client)) {
                    if a.accepted {
                        return Err("arrival accepted on a failed edge".into());
                    }
                    if a.weight != 0.0 {
                        return Err("stranded arrival kept weight".into());
                    }
                }
            }
            if plan.delivered > 0 {
                let sum: f32 =
                    plan.arrivals.iter().filter(|a| a.accepted).map(|a| a.weight).sum();
                if (sum - 1.0).abs() > 1e-4 {
                    return Err(format!("Σp over surviving edges = {sum}"));
                }
            }
            if plan.delivered + plan.stragglers_cut != plan.computing.len() {
                return Err("lifecycle bookkeeping out of balance".into());
            }
        }
        Ok(())
    });
}
