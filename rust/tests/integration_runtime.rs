//! Cross-language integration: the rust SRHT mirror and the AOT HLO
//! artifacts must realize the *same* operator, and the artifact outputs
//! must satisfy the paper's algebraic identities.
//!
//! Requires `make artifacts` (skips gracefully otherwise).
//!
//! Note: PJRT handles are not Send/Sync (the xla crate wraps raw
//! pointers), so each #[test] — which cargo runs on its own thread —
//! builds its own client; checks are grouped to amortize compilation.

use pfed1bs::runtime::{ModelRuntime, Runtime};
use pfed1bs::sketch::SrhtOperator;
use pfed1bs::util::rng::Rng;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn load_model() -> (ModelRuntime, SrhtOperator) {
    let rt = Runtime::new("artifacts").expect("runtime");
    let info = rt.manifest.get("client_step", "mlp784").expect("manifest");
    let op = SrhtOperator::from_seed(999, info.n, info.m);
    let model = rt.model("mlp784", &op).expect("model");
    (model, op)
}

fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| 0.1 * rng.normal()).collect()
}

#[test]
fn hlo_artifacts_agree_with_rust_mirror_and_each_other() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (m, op) = load_model();
    let g = m.geom;
    let mut rng = Rng::new(1);

    // (a) HLO sketch == rust mirror sketch, bit-for-bit (up to exact-zero
    // crossings where f32 summation order may differ)
    for trial in 0..3 {
        let w = rand_w(&mut rng, g.n);
        let hlo = m.sketch_sign(&w).expect("hlo sketch");
        let rust = op.sketch_sign(&w);
        let diff = hlo.iter().zip(&rust).filter(|(a, b)| a != b).count();
        assert!(
            diff <= g.m / 1000,
            "trial {trial}: {diff}/{} sketch bits differ",
            g.m
        );
    }

    // (b) client_step with lambda=0 == sgd_step exactly
    let w = rand_w(&mut rng, g.n);
    let x: Vec<f32> = (0..g.train_batch * g.input_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..g.train_batch).map(|_| rng.below(g.classes) as i32).collect();
    let v = vec![1.0f32; g.m];
    let (a, la) = m.client_step(&w, &x, &y, &v, 0.1, 0.0, 1e-5, 1e4).unwrap();
    let (b, lb) = m.sgd_step(&w, &x, &y, 0.1, 1e-5).unwrap();
    assert!((la - lb).abs() < 1e-5);
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "lambda=0 mismatch {max_diff}");

    // (c) geometry mismatch rejected
    let rt = Runtime::new("artifacts").expect("runtime");
    let bad_op = SrhtOperator::from_seed(1, 100, 10);
    assert!(rt.model("mlp784", &bad_op).is_err());
}

#[test]
fn client_step_descends_and_grad_norm_shrinks() {
    if !artifacts_available() {
        return;
    }
    let (m, _) = load_model();
    let g = m.geom;
    let mut rng = Rng::new(2);
    let mut w = rand_w(&mut rng, g.n);
    let x: Vec<f32> = (0..g.train_batch * g.input_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..g.train_batch).map(|_| rng.below(g.classes) as i32).collect();
    let v = vec![0.0f32; g.m];

    let (w1, loss1) = m.client_step(&w, &x, &y, &v, 0.05, 5e-4, 1e-5, 1e4).unwrap();
    assert_eq!(w1.len(), g.n);
    assert!(loss1.is_finite() && loss1 > 0.0);
    assert!(w1.iter().zip(&w).any(|(a, b)| a != b), "step must move w");
    let (_, loss2) = m.client_step(&w1, &x, &y, &v, 0.05, 5e-4, 1e-5, 1e4).unwrap();
    assert!(loss2 <= loss1 + 1e-3, "same-batch loss went up: {loss1} -> {loss2}");

    // Theorem-1 diagnostic: same-batch gradient norm shrinks with training
    let gn0 = m.grad_norm(&w, &x, &y, &v, 5e-4, 1e-5, 1e4).unwrap();
    assert!(gn0.is_finite() && gn0 > 0.0);
    for _ in 0..20 {
        let (w_next, _) = m.client_step(&w, &x, &y, &v, 0.05, 5e-4, 1e-5, 1e4).unwrap();
        w = w_next;
    }
    let gn1 = m.grad_norm(&w, &x, &y, &v, 5e-4, 1e-5, 1e4).unwrap();
    assert!(gn1 < gn0, "gradient norm did not shrink: {gn0} -> {gn1}");
}

#[test]
fn eval_batch_masks_padding_rows() {
    if !artifacts_available() {
        return;
    }
    let (m, _) = load_model();
    let g = m.geom;
    let mut rng = Rng::new(4);
    let w = rand_w(&mut rng, g.n);
    let x: Vec<f32> = (0..g.eval_batch * g.input_dim).map(|_| rng.normal()).collect();
    let mut y: Vec<i32> = (0..g.eval_batch).map(|_| rng.below(g.classes) as i32).collect();

    let (c_full, l_full) = m.eval_batch(&w, &x, &y).unwrap();
    for yi in y.iter_mut().skip(g.eval_batch / 2) {
        *yi = -1;
    }
    let (c_half, l_half) = m.eval_batch(&w, &x, &y).unwrap();
    assert!(c_half <= c_full);
    assert!(l_half <= l_full + 1e-3);
    assert!(c_half <= (g.eval_batch / 2) as f32);
}
