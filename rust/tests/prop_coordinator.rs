//! Property tests over coordinator-level invariants that do NOT need the
//! PJRT runtime: client sampling, weight normalization, ledger shard
//! merging, vote stability, codec/transport round trips, partition
//! coverage, the event engine's delivered-set planning, and the pFed1BS
//! noisy-downlink / streaming-aggregation protocol regressions.
//! (Runtime-dependent invariants live in integration_training.rs.)

use pfed1bs::algorithms::{
    AggKind, Algorithm, ClientOutput, ClientStats, RoundAggregator, ServerCtx, Uplink,
};
use pfed1bs::comm::{decode, encode, Direction, LatencyModel, Ledger, Payload, SimNetwork};
use pfed1bs::config::{Attack, RunConfig, Topology};
use pfed1bs::coordinator::parallel::par_map_consume;
use pfed1bs::coordinator::{plan_round, plan_round_buffered, RoundPlan};
use pfed1bs::data::{generate, DatasetName, DatasetSpec, Partition};
use pfed1bs::sketch::bitpack::{
    majority_vote_weighted, GroupedTally, SignVec, VoteAccumulator,
};
use pfed1bs::sketch::{Projection, SrhtOperator};
use pfed1bs::util::proptest::check;
use pfed1bs::util::rng::Rng;

fn small_spec(classes: usize) -> DatasetSpec {
    DatasetSpec {
        name: DatasetName::Mnist,
        input_dim: 8,
        classes,
        noise: 0.5,
        proto_scale: 2.0,
        shift_scale: 0.3,
        train_per_client: 12,
        test_per_client: 6,
        }
}

#[test]
fn prop_sampled_clients_unique_and_weights_normalized() {
    check("sampling_weights", 100, |rng| {
        let k = rng.below(40) + 1;
        let s = rng.below(k) + 1;
        let selected = rng.sample_without_replacement(k, s);
        let mut dedup = selected.clone();
        dedup.sort_unstable();
        dedup.dedup();
        if dedup.len() != s {
            return Err("duplicate clients in a round".into());
        }
        // normalize arbitrary positive weights over the subset
        let raw: Vec<f32> = selected.iter().map(|_| rng.f32() + 0.01).collect();
        let total: f32 = raw.iter().sum();
        let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();
        let sum: f32 = weights.iter().sum();
        if (sum - 1.0).abs() > 1e-4 {
            return Err(format!("weights sum {sum}"));
        }
        Ok(())
    });
}

#[test]
fn prop_every_selected_client_updated_exactly_once() {
    // the coordinator hands each selected id to the algorithm exactly
    // once per round; model this with a counting 'algorithm'
    check("one_update_per_client", 50, |rng| {
        let k = rng.below(30) + 2;
        let s = rng.below(k) + 1;
        let mut counts = vec![0usize; k];
        for &kid in &rng.sample_without_replacement(k, s) {
            counts[kid] += 1;
        }
        if counts.iter().any(|&c| c > 1) {
            return Err("client updated twice".into());
        }
        if counts.iter().filter(|&&c| c == 1).count() != s {
            return Err("wrong update count".into());
        }
        Ok(())
    });
}

#[test]
fn prop_transport_preserves_sign_payloads_and_meters_bytes() {
    check("transport_round_trip", 50, |rng| {
        let m = rng.below(2000) + 1;
        let signs: Vec<f32> = (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let mut net = SimNetwork::new(rng.next_u64());
        let sent = Payload::Signs(SignVec::from_signs(&signs));
        let got = net.uplink_from(0, &sent).map_err(|e| e.to_string())?;
        if got != sent {
            return Err("clean channel altered payload".into());
        }
        let bytes = net.end_round();
        if bytes.uplink != encode(&sent).len() as u64 {
            return Err("ledger bytes != frame bytes".into());
        }
        if bytes.downlink != 0 {
            return Err("phantom downlink".into());
        }
        Ok(())
    });
}

#[test]
fn prop_vote_unanimous_is_identity_and_stable_under_duplicates() {
    check("vote_stability", 50, |rng| {
        let m = rng.below(300) + 1;
        let z: Vec<f32> = (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let packed = SignVec::from_signs(&z);
        // unanimous clients: vote == the sketch, any weights
        let kk = rng.below(6) + 1;
        let sketches: Vec<SignVec> = (0..kk).map(|_| packed.clone()).collect();
        let mut w: Vec<f32> = (0..kk).map(|_| rng.f32() + 0.01).collect();
        let t: f32 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= t);
        let vote = majority_vote_weighted(&sketches, &w, m).to_signs();
        if vote != z {
            return Err("unanimous vote changed bits".into());
        }
        Ok(())
    });
}

#[test]
fn prop_vote_flips_with_weighted_majority() {
    check("vote_majority_semantics", 50, |rng| {
        let m = rng.below(100) + 1;
        let plus = vec![1.0f32; m];
        let minus = vec![-1.0f32; m];
        let p_plus = rng.f32() * 0.98 + 0.01;
        let weights = vec![p_plus, 1.0 - p_plus];
        let sketches = vec![SignVec::from_signs(&plus), SignVec::from_signs(&minus)];
        let vote = majority_vote_weighted(&sketches, &weights, m).to_signs();
        let want = if p_plus >= 0.5 { 1.0 } else { -1.0 };
        if vote.iter().any(|&v| v != want) {
            return Err(format!("p_plus={p_plus} vote wrong"));
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_cover_and_respect_bounds() {
    check("partition_bounds", 40, |rng| {
        let clients = rng.below(25) + 1;
        let classes = rng.below(15) + 1;
        let spec = small_spec(classes);
        let per_client = rng.below(classes) + 1;
        let fd = generate(
            &spec,
            clients,
            &Partition::LabelShards { per_client },
            rng.next_u64(),
        );
        if fd.num_clients() != clients {
            return Err("client count".into());
        }
        let wsum: f32 = fd.weights.iter().sum();
        if (wsum - 1.0).abs() > 1e-4 {
            return Err(format!("weights sum {wsum}"));
        }
        for c in &fd.clients {
            if c.train_len() != spec.train_per_client {
                return Err("train size".into());
            }
            for &y in &c.train_y {
                if !(0..classes as i32).contains(&y) {
                    return Err(format!("label {y} out of range"));
                }
                if !c.classes.contains(&(y as usize)) {
                    return Err("label outside client's shard".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_srht_sketch_agreement_between_two_honest_parties() {
    // the paper's seed-broadcast protocol: server and client building the
    // operator from the same seed must produce identical sketches
    check("seed_agreement", 30, |rng| {
        let n = rng.below(500) + 10;
        let m = (n / 10).max(1);
        let seed = rng.next_u64();
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let a = SrhtOperator::from_seed(seed, n, m).sketch_sign(&w);
        let b = SrhtOperator::from_seed(seed, n, m).sketch_sign(&w);
        if a != b {
            return Err("same seed produced different sketches".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bit_flip_noise_rate_is_calibrated() {
    check("noise_rate", 10, |rng| {
        let p = rng.f64() * 0.3;
        let mut net = SimNetwork::new(rng.next_u64()).with_bit_flips(p);
        let n = 20_000;
        let sent = Payload::Signs(SignVec::from_signs(&vec![1.0; n]));
        let Payload::Signs(got) = net.uplink_from(0, &sent).map_err(|e| e.to_string())? else {
            return Err("type".into());
        };
        // the packed masked-XOR corruption must still flip ~p of the bits
        let flipped = (n - got.words().iter().map(|w| w.count_ones() as usize).sum::<usize>())
            as f64
            / n as f64;
        if (flipped - p).abs() > 0.02 {
            return Err(format!("flip rate {flipped} vs p={p}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_metering_equals_serial_ledger() {
    // for random traffic patterns, the merged per-client shards must be
    // byte- and message-count-identical to one serial ledger
    check("ledger_shard_merge", 30, |rng| {
        let clients = rng.below(6) + 1;
        let mut net = SimNetwork::new(rng.next_u64());
        let mut serial = Ledger::new();
        for _ in 0..rng.below(40) {
            let k = rng.below(clients);
            let len = rng.below(300) + 1;
            let payload = match rng.below(3) {
                0 => Payload::Dense(vec![0.5; len]),
                1 => Payload::Signs(SignVec::from_signs(&vec![1.0; len])),
                _ => Payload::ScaledSigns {
                    signs: SignVec::from_signs(&vec![-1.0; len]),
                    scale: 2.0,
                },
            };
            let frame = encode(&payload).len();
            if rng.f32() < 0.5 {
                net.uplink_from(k, &payload).map_err(|e| e.to_string())?;
                serial.record(Direction::Uplink, frame);
            } else {
                net.downlink_to(k, &payload).map_err(|e| e.to_string())?;
                serial.record(Direction::Downlink, frame);
            }
        }
        let merged = net.end_round();
        let reference = serial.end_round();
        if merged != reference {
            return Err(format!("merged {merged:?} != serial {reference:?}"));
        }
        Ok(())
    });
}

#[test]
fn regression_noisy_downlink_never_corrupts_server_consensus() {
    // the monolithic round() overwrote the server's v with the first
    // bit-flipped delivered copy and handed every client that same
    // corruption; the phased protocol must (a) keep the server's v
    // noise-free and (b) deliver independently corrupted copies
    let m = 256;
    let n = 64;
    // protocol-level state without the PJRT init path
    let mut alg = pfed1bs::algorithms::pfed1bs::PFed1BS::with_state(
        vec![vec![0.0f32; n]; 4],
        vec![1.0f32; m],
    );

    let down = alg.server_broadcast(1).expect("t>0 broadcasts the consensus");
    let mut net = SimNetwork::new(99).with_bit_flips(0.25);
    let d0 = net.downlink_to(0, &down.payload).unwrap();
    let d1 = net.downlink_to(1, &down.payload).unwrap();
    assert_ne!(d0, d1, "clients must receive independently corrupted copies");
    assert_ne!(d0, down.payload);
    assert_eq!(
        alg.consensus().unwrap(),
        vec![1.0f32; m].as_slice(),
        "server consensus must be untouched by channel corruption"
    );

    // the next consensus is the vote over DELIVERED uplinks only — the
    // corrupted downlink copies play no role in server state. The
    // streaming path: absorb each delivered uplink as it arrives.
    let cfg = RunConfig::preset(DatasetName::Mnist);
    let projection = Projection::Srht(SrhtOperator::from_seed(1, n, m.min(n)));
    let ctx = ServerCtx { cfg: &cfg, projection: &projection };
    let mut agg = alg.begin_aggregate(1);
    for k in 0..2 {
        let out = ClientOutput {
            client: k,
            uplink: Some(Uplink::new(1, Payload::Signs(SignVec::from_signs(&vec![-1.0f32; m])))),
            state: None,
            stats: ClientStats::default(),
        };
        agg.absorb(out, 0.5).unwrap();
    }
    alg.finish_aggregate(1, agg, &ctx).unwrap();
    assert_eq!(alg.consensus().unwrap(), vec![-1.0f32; m].as_slice());
    // the packed mirror (what the next broadcast ships) must agree
    assert_eq!(
        alg.consensus_packed().unwrap().to_signs(),
        vec![-1.0f32; m]
    );
}

/// Protocol-level golden, runnable with no PJRT artifacts: a hand-built
/// pFed1BS aggregation whose consensus is analytically determined, with
/// the exact packed words asserted bit-for-bit. Weights are chosen
/// binary-exact (0.5/0.25/0.25) so the fixed-point tally has a
/// mathematically unambiguous sign at every bit (the only tie,
/// −0.5+0.25+0.25 = 0.0, is exact and breaks toward +1 by the
/// `sign(0) := +1` convention). Unlike the artifact-gated golden-trace
/// test, this one runs everywhere CI runs — the streamed server vote,
/// transport round trip, and byte metering cannot drift silently.
#[test]
fn golden_protocol_vote_and_wire_bytes_without_runtime() {
    let m = 130; // three words, 2-bit tail
    let n = 16;
    let mut alg = pfed1bs::algorithms::pfed1bs::PFed1BS::with_state(
        vec![vec![0.0f32; n]; 3],
        vec![1.0f32; m],
    );

    // client sketches: z0 = +1 at even i, z1 = +1 at i % 3 == 0, z2 = +1
    let z0 = SignVec::from_fn(m, |i| i % 2 == 0);
    let z1 = SignVec::from_fn(m, |i| i % 3 == 0);
    let z2 = SignVec::from_fn(m, |_| true);
    // transport each through its own clean channel (exact metering)
    let mut net = SimNetwork::new(7);
    let outputs: Vec<ClientOutput> = [z0, z1, z2]
        .into_iter()
        .enumerate()
        .map(|(k, z)| {
            let delivered = net.uplink_from(k, &Payload::Signs(z)).unwrap();
            ClientOutput {
                client: k,
                uplink: Some(Uplink::new(1, delivered)),
                state: None,
                stats: ClientStats::default(),
            }
        })
        .collect();
    let bytes = net.end_round();
    assert_eq!(bytes.uplink, 3 * (5 + 24), "130 bits -> 3 words -> 24 bytes + header");
    assert_eq!(bytes.uplink_msgs, 3);

    // weighted vote with p = [0.5, 0.25, 0.25]:
    //   i even, i%3==0 : +0.5 +0.25 +0.25 = +1.0  -> +1
    //   i even, i%3!=0 : +0.5 -0.25 +0.25 = +0.5  -> +1
    //   i odd,  i%3==0 : -0.5 +0.25 +0.25 =  0.0  -> +1 (tie toward +1)
    //   i odd,  i%3!=0 : -0.5 -0.25 +0.25 = -0.5  -> -1
    // Streamed one uplink at a time — and, because the tally is exact
    // fixed point, absorbing in REVERSE arrival order must produce the
    // same words bit-for-bit.
    let cfg = RunConfig::preset(DatasetName::Mnist);
    let projection = Projection::Srht(SrhtOperator::from_seed(1, n, n));
    let ctx = ServerCtx { cfg: &cfg, projection: &projection };
    let weights = [0.5f32, 0.25, 0.25];
    let mut reversed = alg.begin_aggregate(1);
    for (out, &w) in outputs.iter().zip(&weights).rev() {
        reversed.absorb(out.clone(), w).unwrap();
    }
    let mut agg = alg.begin_aggregate(1);
    for (out, &w) in outputs.into_iter().zip(&weights) {
        agg.absorb(out, w).unwrap();
    }
    alg.finish_aggregate(1, agg, &ctx).unwrap();

    // i.e. bit set iff i is even or divisible by 3
    let want = SignVec::from_fn(m, |i| i % 2 == 0 || i % 3 == 0);
    let got = alg.consensus_packed().unwrap();
    assert_eq!(got, &want, "vote words diverged from the analytic consensus");
    // and the exact packed words, spelled out: bit clear iff i ∈ {1, 5}
    // mod 6 — per 6-bit block the pattern is 0b011101 = 0x1D, the block
    // straddling word boundaries; pin the first word and the 2-bit tail.
    let w0 = (0..64u64).fold(0u64, |acc, i| {
        if i % 2 == 0 || i % 3 == 0 {
            acc | 1u64 << i
        } else {
            acc
        }
    });
    assert_eq!(got.words()[0], w0);
    // bits 128, 129: i=128 even -> 1; i=129 odd, 129%3==0 -> 1 (tie)
    assert_eq!(got.words()[2], 0b11);
    // arrival-order invariance at the protocol level: the reverse-order
    // aggregator's tally signs into the same words bit-for-bit
    let (AggKind::Vote(tally), _, 3, _) = reversed.into_parts() else {
        panic!("pfed1bs aggregator must be the vote tally");
    };
    assert_eq!(tally.finish(), want, "reverse arrival order changed the vote");
}

/// Scenario planning runs everywhere (no PJRT needed): the delivered-set
/// weight renormalization and lifecycle bookkeeping of the event engine,
/// across random scenario knobs.
#[test]
fn prop_round_plan_renormalizes_weights_over_the_delivered_set() {
    check("plan_delivered_renorm", 40, |rng| {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.clients = rng.below(30) + 2;
        cfg.participating = rng.below(cfg.clients) + 1;
        cfg.over_select = rng.below(cfg.clients - cfg.participating + 1);
        cfg.dropout_prob = rng.f64() * 0.5;
        cfg.deadline_ms = if rng.f32() < 0.5 { 0.0 } else { 5.0 + rng.f64() * 20.0 };
        cfg.latency = match rng.below(3) {
            0 => LatencyModel::Zero,
            1 => LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 40.0 },
            _ => LatencyModel::LogNormal { median_ms: 10.0, sigma: 0.8 },
        };
        cfg.validate().map_err(|e| e.to_string())?;
        // arbitrary positive fleet weights, normalized like data.weights
        let raw: Vec<f32> = (0..cfg.clients).map(|_| rng.f32() + 0.01).collect();
        let total: f32 = raw.iter().sum();
        let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();

        let mut net = SimNetwork::new(rng.next_u64());
        let mut coord_rng = Rng::new(rng.next_u64());
        for t in 0..3 {
            let plan = plan_round(t, &cfg, &weights, &mut net, &mut coord_rng);
            if plan.computing.len() + plan.dropped != plan.selected.len() {
                return Err("computing + dropped != cohort".into());
            }
            if plan.delivered + plan.stragglers_cut != plan.computing.len() {
                return Err("delivered + cut != computing".into());
            }
            if plan.delivered > cfg.participating {
                return Err("delivered more than the target S".into());
            }
            if plan.delivered > 0 {
                let sum: f32 = plan
                    .arrivals
                    .iter()
                    .filter(|a| a.accepted)
                    .map(|a| a.weight)
                    .sum();
                if (sum - 1.0).abs() > 1e-4 {
                    return Err(format!("delivered weights sum to {sum}"));
                }
            }
            // no scenario knobs -> exactly the barrier round
            if !cfg.has_scenario()
                && (plan.delivered != cfg.participating || plan.stragglers_cut != 0)
            {
                return Err("default knobs must deliver the whole cohort".into());
            }
        }
        Ok(())
    });
}

/// Three planned rounds of `cfg` over a deterministic transport/RNG pair
/// (zero carry — the barrier entry point).
fn three_plans(cfg: &RunConfig, seed: u64, weights: &[f32]) -> Vec<RoundPlan> {
    let mut net = SimNetwork::new(seed);
    let mut prng = Rng::new(seed ^ 0x504C_414E);
    (0..3).map(|t| plan_round_buffered(t, cfg, weights, 0.0, &mut net, &mut prng)).collect()
}

/// Field-by-field (weights BITWISE) plan equality, plus the barrier
/// shape itself: no quorum close, no buffered arrivals.
fn assert_barrier_identical(a: &RoundPlan, b: &RoundPlan) -> Result<(), String> {
    if a.quorum_closed || b.quorum_closed {
        return Err("barrier plan claimed a quorum close".into());
    }
    if a.buffered_late != 0 || b.buffered_late != 0 {
        return Err("barrier plan buffered a late arrival".into());
    }
    if a.selected != b.selected
        || a.computing != b.computing
        || a.delivered != b.delivered
        || a.stragglers_cut != b.stragglers_cut
        || a.dropped != b.dropped
        || a.failed_edges != b.failed_edges
    {
        return Err("plan lifecycle fields diverged".into());
    }
    if a.norm_total.to_bits() != b.norm_total.to_bits() {
        return Err("norm_total bits diverged".into());
    }
    if a.arrivals.len() != b.arrivals.len() {
        return Err("arrival counts diverged".into());
    }
    if a.adversaries != b.adversaries {
        return Err("adversary counts diverged".into());
    }
    for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
        if x.buffered || y.buffered || x.staleness != 0 || y.staleness != 0 {
            return Err("barrier arrival carried staleness state".into());
        }
        if (x.task, x.client, x.accepted, x.adversarial)
            != (y.task, y.client, y.accepted, y.adversarial)
            || x.at_ms.to_bits() != y.at_ms.to_bits()
            || x.weight.to_bits() != y.weight.to_bits()
        {
            return Err("arrival bits diverged".into());
        }
    }
    Ok(())
}

/// DESIGN.md §13's reduction argument, pinned as a property: with the
/// quorum/staleness knobs at their defaults — and equally under the
/// explicit barrier spelling `quorum = S`, `max_staleness = 0` with a
/// non-default (inert) decay — the async engine IS the barrier engine.
/// Plans agree bit for bit across random scenario knobs and topologies
/// {flat, edge:4}; the tally quanta through the engine's own
/// `par_map_consume` absorb shape agree across threads {1, 4}; and the
/// metered per-round wire bytes agree between the two spellings.
#[test]
fn prop_default_quorum_knobs_reduce_to_the_barrier_engine_bit_for_bit() {
    check("quorum_default_reduction", 10, |rng| {
        for edges in [0usize, 4] {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.clients = rng.below(24) + 8;
            cfg.participating = rng.below(cfg.clients - 1) + 2;
            cfg.over_select = rng.below((cfg.clients - cfg.participating).min(4) + 1);
            cfg.dropout_prob = rng.f64() * 0.4;
            cfg.deadline_ms = if rng.f32() < 0.5 { 0.0 } else { 5.0 + rng.f64() * 20.0 };
            cfg.latency = match rng.below(3) {
                0 => LatencyModel::Zero,
                1 => LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 40.0 },
                _ => LatencyModel::LogNormal { median_ms: 10.0, sigma: 0.8 },
            };
            if edges > 0 {
                cfg.topology = Topology::Edge { edges };
                cfg.edge_dropout_prob = rng.f64() * 0.3;
            }
            cfg.validate().map_err(|e| e.to_string())?;
            // the same run with the barrier spelled explicitly; the
            // decay knob must be inert while max_staleness = 0
            let mut explicit = cfg.clone();
            explicit.quorum = explicit.participating;
            explicit.staleness_decay = 0.25;
            explicit.validate().map_err(|e| e.to_string())?;

            let seed = rng.next_u64();
            let raw: Vec<f32> = (0..cfg.clients).map(|_| rng.f32() + 0.01).collect();
            let total: f32 = raw.iter().sum();
            let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();

            let plans = three_plans(&cfg, seed, &weights);
            let plans_explicit = three_plans(&explicit, seed, &weights);
            for (a, b) in plans.iter().zip(&plans_explicit) {
                assert_barrier_identical(a, b)?;
            }

            // tally-quanta identity through the engine's absorb shape:
            // worker threads "compute", the caller thread folds each
            // arrival into its edge shard in plan-arrival order
            let m = 130;
            let topo = cfg.topology;
            for plan in &plans {
                let outputs: Vec<ClientOutput> = plan
                    .computing
                    .iter()
                    .map(|&k| ClientOutput {
                        client: k,
                        uplink: Some(Uplink::new(
                            plan.t as u32,
                            Payload::Signs(SignVec::from_fn(m, |i| (i + k) % 3 != 0)),
                        )),
                        state: None,
                        stats: ClientStats::default(),
                    })
                    .collect();
                // serial flat oracle in arrival order
                let mut flat = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)));
                for a in &plan.arrivals {
                    let out = outputs[a.task].clone();
                    if a.accepted {
                        flat.absorb(out, a.weight).map_err(|e| e.to_string())?;
                    } else {
                        flat.absorb_cut(out);
                    }
                }
                let (AggKind::Vote(want), _, want_absorbed, _) = flat.into_parts() else {
                    return Err("oracle kind".into());
                };
                let order: Vec<usize> = plan.arrivals.iter().map(|a| a.task).collect();
                for threads in [1usize, 4] {
                    let mut shards: Vec<RoundAggregator> = (0..topo.shards())
                        .map(|_| RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m))))
                        .collect();
                    let mut arrivals = plan.arrivals.iter();
                    par_map_consume(
                        outputs.clone(),
                        threads,
                        &order,
                        |_, out: ClientOutput| out,
                        |_, out: ClientOutput| -> Result<(), String> {
                            let a = arrivals.next().expect("one arrival per task");
                            let shard = &mut shards[topo.edge_of(out.client)];
                            if a.accepted {
                                shard.absorb(out, a.weight).map_err(|e| e.to_string())
                            } else {
                                shard.absorb_cut(out);
                                Ok(())
                            }
                        },
                    )?;
                    let mut it = shards.into_iter();
                    let mut root = it.next().unwrap();
                    for s in it {
                        root.merge(s).map_err(|e| e.to_string())?;
                    }
                    let (AggKind::Vote(got), _, absorbed, _) = root.into_parts() else {
                        return Err("merged kind".into());
                    };
                    if got.quanta() != want.quanta() || absorbed != want_absorbed {
                        return Err(format!(
                            "threads={threads} edges={edges} t={}: tally quanta diverged",
                            plan.t
                        ));
                    }
                }

                // per-round wire bytes agree between the two spellings:
                // both plans ship the same uplinks through a clean net
                let mut bytes = [0u64; 2];
                for (slot, p) in [(0usize, plan), (1, &plans_explicit[plan.t])] {
                    let mut net = SimNetwork::new(seed ^ 0xB17E);
                    for a in &p.arrivals {
                        net.uplink_from(
                            a.client,
                            &Payload::Signs(SignVec::from_fn(m, |i| (i + a.client) % 3 != 0)),
                        )
                        .map_err(|e| e.to_string())?;
                    }
                    bytes[slot] = net.end_round().uplink;
                }
                if bytes[0] != bytes[1] {
                    return Err("wire bytes diverged between barrier spellings".into());
                }
            }
        }
        Ok(())
    });
}

/// Arming an attack must not perturb planning (DESIGN.md §16): the
/// adversary draw is a stateless SplitMix64 stream, so across random
/// scenario knobs the armed plan matches the honest plan bit for bit in
/// every field except the marks themselves; under `attack = none` no
/// arrival is ever marked; and the marks replay identically — they are
/// a pure function of `(seed, t, k)`, not of planner state.
#[test]
fn prop_attack_marks_are_stateless_and_plan_inert() {
    check("attack_plan_inert", 20, |rng| {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.clients = rng.below(20) + 6;
        cfg.participating = rng.below(cfg.clients - 2) + 2;
        cfg.dropout_prob = rng.f64() * 0.3;
        if rng.f32() < 0.5 {
            cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 20.0 };
            cfg.deadline_ms = 10.0;
        }
        cfg.validate().map_err(|e| e.to_string())?;
        let mut armed = cfg.clone();
        armed.attack = Attack::SignFlip { frac: 0.2 + rng.f64() * 0.6 };
        armed.validate().map_err(|e| e.to_string())?;

        let seed = rng.next_u64();
        let raw: Vec<f32> = (0..cfg.clients).map(|_| rng.f32() + 0.01).collect();
        let total: f32 = raw.iter().sum();
        let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();
        let honest = three_plans(&cfg, seed, &weights);
        let hostile = three_plans(&armed, seed, &weights);
        let replay = three_plans(&armed, seed, &weights);
        for ((h, a), a2) in honest.iter().zip(&hostile).zip(&replay) {
            if h.adversaries != 0 || h.arrivals.iter().any(|x| x.adversarial) {
                return Err("attack=none marked an arrival".into());
            }
            if h.selected != a.selected
                || h.computing != a.computing
                || h.delivered != a.delivered
                || h.dropped != a.dropped
                || h.stragglers_cut != a.stragglers_cut
                || h.norm_total.to_bits() != a.norm_total.to_bits()
            {
                return Err("arming the attack perturbed the plan".into());
            }
            for (x, y) in h.arrivals.iter().zip(&a.arrivals) {
                if (x.task, x.client, x.accepted) != (y.task, y.client, y.accepted)
                    || x.at_ms.to_bits() != y.at_ms.to_bits()
                    || x.weight.to_bits() != y.weight.to_bits()
                {
                    return Err("arrival bits diverged under the attack knob".into());
                }
            }
            let marks: Vec<bool> = a.arrivals.iter().map(|x| x.adversarial).collect();
            let marks2: Vec<bool> = a2.arrivals.iter().map(|x| x.adversarial).collect();
            if marks != marks2 {
                return Err("adversary marks failed to replay".into());
            }
            if a.adversaries != marks.iter().filter(|&&b| b).count() {
                return Err("plan adversary count != marked arrivals".into());
            }
        }
        Ok(())
    });
}

/// DESIGN.md §16's robust tallies at the aggregator level: disarmed
/// (`trim = 0` / one group) they reproduce the plain vote bit for bit,
/// and armed or disarmed the per-group quanta and finished consensus
/// are invariant to shard count 1..5, absorb permutation, worker
/// threads {1, 4}, and whether shards merge in memory or over tag-5
/// wire frames — both the owned decode and the zero-copy view.
#[test]
fn prop_robust_tallies_reduce_to_vote_and_merge_exactly() {
    // pull (per-group quanta, per-group absorbed, finished consensus)
    // out of a closed robust aggregator
    fn robust_parts(
        kind: AggKind,
    ) -> Result<(Vec<Vec<i128>>, Vec<usize>, SignVec), String> {
        match kind {
            AggKind::TrimmedVote { tally, trim_frac } => Ok((
                tally.groups().iter().map(|g| g.quanta().to_vec()).collect(),
                tally.groups().iter().map(|g| g.absorbed()).collect(),
                tally.finish_trimmed(trim_frac),
            )),
            AggKind::MedianOfMeans { groups } => Ok((
                groups.groups().iter().map(|g| g.quanta().to_vec()).collect(),
                groups.groups().iter().map(|g| g.absorbed()).collect(),
                groups.finish_median(),
            )),
            _ => Err("not a robust aggregator kind".into()),
        }
    }

    check("robust_tally_exactness", 8, |rng| {
        let m = rng.below(180) + 1;
        let clients = rng.below(10) + 3;
        let weights: Vec<f32> = (0..clients).map(|_| rng.f32() + 0.05).collect();
        let mut outs: Vec<ClientOutput> = Vec::with_capacity(clients);
        for k in 0..clients {
            let z = SignVec::from_fn(m, |_| rng.f32() < 0.5);
            outs.push(ClientOutput {
                client: k,
                uplink: Some(Uplink::new(0, Payload::Signs(z))),
                state: None,
                stats: ClientStats::default(),
            });
        }

        // the plain-vote oracle over the same uplinks
        let mut vote = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)));
        for (k, out) in outs.iter().enumerate() {
            vote.absorb(out.clone(), weights[k]).map_err(|e| e.to_string())?;
        }
        let (AggKind::Vote(vt), _, _, _) = vote.into_parts() else {
            return Err("vote oracle kind".into());
        };

        // (trimmed?, trim_frac, group count, reduces-to-vote?)
        let mom_g = rng.below(3) + 2;
        let arms: [(bool, f64, usize, bool); 4] = [
            (true, 0.0, clients, true),
            (false, 0.0, 1, true),
            (true, 0.25, clients, false),
            (false, 0.0, mom_g, false),
        ];
        for (trimmed, trim_frac, g, disarmed) in arms {
            let fresh = || {
                if trimmed {
                    RoundAggregator::new(AggKind::TrimmedVote {
                        tally: GroupedTally::new(m, g),
                        trim_frac,
                    })
                } else {
                    RoundAggregator::new(AggKind::MedianOfMeans {
                        groups: GroupedTally::new(m, g),
                    })
                }
            };
            // flat reference in selection order
            let mut flat = fresh();
            for (k, out) in outs.iter().enumerate() {
                flat.absorb(out.clone(), weights[k]).map_err(|e| e.to_string())?;
            }
            let (want_q, want_a, want_v) = robust_parts(flat.into_parts().0)?;
            if disarmed {
                let total: Vec<i128> = (0..m)
                    .map(|i| want_q.iter().map(|gq| gq[i]).sum::<i128>())
                    .collect();
                if total != vt.quanta() {
                    return Err("disarmed robust quanta != vote quanta".into());
                }
                if want_v != vt.finish() {
                    return Err("disarmed robust finish != vote finish".into());
                }
            }

            for shards in 1..=5usize {
                let mut order: Vec<usize> = (0..clients).collect();
                rng.shuffle(&mut order);
                let mut parts: Vec<RoundAggregator> =
                    (0..shards).map(|_| fresh()).collect();
                for &k in &order {
                    parts[k % shards]
                        .absorb(outs[k].clone(), weights[k])
                        .map_err(|e| e.to_string())?;
                }

                // wire merges first (merge_payload borrows the shards):
                // one root over owned decodes, one over zero-copy views
                let mut root_owned = fresh();
                let mut root_view = fresh();
                for p in &parts {
                    let frame = p.merge_payload().ok_or("robust kind shipped no frame")?;
                    let bytes = encode(&frame);
                    root_owned
                        .absorb_frame(decode(&bytes).map_err(|e| e.to_string())?)
                        .map_err(|e| e.to_string())?;
                    let view = Payload::decode_borrowed(&bytes).map_err(|e| e.to_string())?;
                    let pfed1bs::comm::codec::PayloadView::TallyFrame(tv) = view else {
                        return Err("grouped frame decoded to a non-tally view".into());
                    };
                    root_view.absorb_frame_view(&tv).map_err(|e| e.to_string())?;
                }
                // then the in-memory merge, consuming the shards
                let mut it = parts.into_iter();
                let mut root_mem = it.next().unwrap();
                for s in it {
                    root_mem.merge(s).map_err(|e| e.to_string())?;
                }

                for (label, root) in
                    [("memory", root_mem), ("owned-wire", root_owned), ("view-wire", root_view)]
                {
                    let (q, a, v) = robust_parts(root.into_parts().0)?;
                    if q != want_q || a != want_a || v != want_v {
                        return Err(format!(
                            "{label} merge diverged (shards={shards}, trimmed={trimmed}, g={g})"
                        ));
                    }
                }
            }

            // engine-shaped threading: worker threads map, the caller
            // thread folds in a fixed order — quanta must not care
            let order: Vec<usize> = (0..clients).collect();
            for threads in [1usize, 4] {
                let mut agg = fresh();
                par_map_consume(
                    outs.clone(),
                    threads,
                    &order,
                    |_, out: ClientOutput| out,
                    |_, out: ClientOutput| -> Result<(), String> {
                        let w = weights[out.client];
                        agg.absorb(out, w).map_err(|e| e.to_string())
                    },
                )?;
                let (q, a, v) = robust_parts(agg.into_parts().0)?;
                if q != want_q || a != want_a || v != want_v {
                    return Err(format!("threads={threads} diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_streams_disjoint_across_clients_and_rounds() {
    check("stream_disjoint", 20, |rng| {
        let mut root = Rng::new(rng.next_u64());
        let a: Vec<u64> = {
            let mut r = root.fork(1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = root.fork(2);
            (0..16).map(|_| r.next_u64()).collect()
        };
        if a == b {
            return Err("forked streams identical".into());
        }
        Ok(())
    });
}
