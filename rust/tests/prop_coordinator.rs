//! Property tests over coordinator-level invariants that do NOT need the
//! PJRT runtime: client sampling, weight normalization, ledger symmetry,
//! vote stability, codec/transport round trips, partition coverage.
//! (Runtime-dependent invariants live in integration_training.rs.)

use pfed1bs::comm::{encode, Payload, SimNetwork};
use pfed1bs::data::{generate, DatasetName, DatasetSpec, Partition};
use pfed1bs::sketch::bitpack::{majority_vote_weighted, pack_signs, unpack_signs};
use pfed1bs::sketch::SrhtOperator;
use pfed1bs::util::proptest::check;
use pfed1bs::util::rng::Rng;

fn small_spec(classes: usize) -> DatasetSpec {
    DatasetSpec {
        name: DatasetName::Mnist,
        input_dim: 8,
        classes,
        noise: 0.5,
        proto_scale: 2.0,
        shift_scale: 0.3,
        train_per_client: 12,
        test_per_client: 6,
        }
}

#[test]
fn prop_sampled_clients_unique_and_weights_normalized() {
    check("sampling_weights", 100, |rng| {
        let k = rng.below(40) + 1;
        let s = rng.below(k) + 1;
        let selected = rng.sample_without_replacement(k, s);
        let mut dedup = selected.clone();
        dedup.sort_unstable();
        dedup.dedup();
        if dedup.len() != s {
            return Err("duplicate clients in a round".into());
        }
        // normalize arbitrary positive weights over the subset
        let raw: Vec<f32> = selected.iter().map(|_| rng.f32() + 0.01).collect();
        let total: f32 = raw.iter().sum();
        let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();
        let sum: f32 = weights.iter().sum();
        if (sum - 1.0).abs() > 1e-4 {
            return Err(format!("weights sum {sum}"));
        }
        Ok(())
    });
}

#[test]
fn prop_every_selected_client_updated_exactly_once() {
    // the coordinator hands each selected id to the algorithm exactly
    // once per round; model this with a counting 'algorithm'
    check("one_update_per_client", 50, |rng| {
        let k = rng.below(30) + 2;
        let s = rng.below(k) + 1;
        let mut counts = vec![0usize; k];
        for &kid in &rng.sample_without_replacement(k, s) {
            counts[kid] += 1;
        }
        if counts.iter().any(|&c| c > 1) {
            return Err("client updated twice".into());
        }
        if counts.iter().filter(|&&c| c == 1).count() != s {
            return Err("wrong update count".into());
        }
        Ok(())
    });
}

#[test]
fn prop_transport_preserves_sign_payloads_and_meters_bytes() {
    check("transport_round_trip", 50, |rng| {
        let m = rng.below(2000) + 1;
        let signs: Vec<f32> = (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let mut net = SimNetwork::new(rng.next_u64());
        let sent = Payload::Signs(signs);
        let got = net.send_uplink(&sent).map_err(|e| e.to_string())?;
        if got != sent {
            return Err("clean channel altered payload".into());
        }
        let bytes = net.end_round();
        if bytes.uplink != encode(&sent).len() as u64 {
            return Err("ledger bytes != frame bytes".into());
        }
        if bytes.downlink != 0 {
            return Err("phantom downlink".into());
        }
        Ok(())
    });
}

#[test]
fn prop_vote_unanimous_is_identity_and_stable_under_duplicates() {
    check("vote_stability", 50, |rng| {
        let m = rng.below(300) + 1;
        let z: Vec<f32> = (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let packed = pack_signs(&z);
        // unanimous clients: vote == the sketch, any weights
        let kk = rng.below(6) + 1;
        let sketches: Vec<Vec<u64>> = (0..kk).map(|_| packed.clone()).collect();
        let mut w: Vec<f32> = (0..kk).map(|_| rng.f32() + 0.01).collect();
        let t: f32 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= t);
        let vote = unpack_signs(&majority_vote_weighted(&sketches, &w, m), m);
        if vote != z {
            return Err("unanimous vote changed bits".into());
        }
        Ok(())
    });
}

#[test]
fn prop_vote_flips_with_weighted_majority() {
    check("vote_majority_semantics", 50, |rng| {
        let m = rng.below(100) + 1;
        let plus = vec![1.0f32; m];
        let minus = vec![-1.0f32; m];
        let p_plus = rng.f32() * 0.98 + 0.01;
        let weights = vec![p_plus, 1.0 - p_plus];
        let sketches = vec![pack_signs(&plus), pack_signs(&minus)];
        let vote = unpack_signs(&majority_vote_weighted(&sketches, &weights, m), m);
        let want = if p_plus >= 0.5 { 1.0 } else { -1.0 };
        if vote.iter().any(|&v| v != want) {
            return Err(format!("p_plus={p_plus} vote wrong"));
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_cover_and_respect_bounds() {
    check("partition_bounds", 40, |rng| {
        let clients = rng.below(25) + 1;
        let classes = rng.below(15) + 1;
        let spec = small_spec(classes);
        let per_client = rng.below(classes) + 1;
        let fd = generate(
            &spec,
            clients,
            &Partition::LabelShards { per_client },
            rng.next_u64(),
        );
        if fd.num_clients() != clients {
            return Err("client count".into());
        }
        let wsum: f32 = fd.weights.iter().sum();
        if (wsum - 1.0).abs() > 1e-4 {
            return Err(format!("weights sum {wsum}"));
        }
        for c in &fd.clients {
            if c.train_len() != spec.train_per_client {
                return Err("train size".into());
            }
            for &y in &c.train_y {
                if !(0..classes as i32).contains(&y) {
                    return Err(format!("label {y} out of range"));
                }
                if !c.classes.contains(&(y as usize)) {
                    return Err("label outside client's shard".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_srht_sketch_agreement_between_two_honest_parties() {
    // the paper's seed-broadcast protocol: server and client building the
    // operator from the same seed must produce identical sketches
    check("seed_agreement", 30, |rng| {
        let n = rng.below(500) + 10;
        let m = (n / 10).max(1);
        let seed = rng.next_u64();
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let a = SrhtOperator::from_seed(seed, n, m).sketch_sign(&w);
        let b = SrhtOperator::from_seed(seed, n, m).sketch_sign(&w);
        if a != b {
            return Err("same seed produced different sketches".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bit_flip_noise_rate_is_calibrated() {
    check("noise_rate", 10, |rng| {
        let p = rng.f64() * 0.3;
        let mut net = SimNetwork::new(rng.next_u64()).with_bit_flips(p);
        let n = 20_000;
        let sent = Payload::Signs(vec![1.0; n]);
        let Payload::Signs(got) = net.send_uplink(&sent).map_err(|e| e.to_string())? else {
            return Err("type".into());
        };
        let flipped = got.iter().filter(|&&s| s < 0.0).count() as f64 / n as f64;
        if (flipped - p).abs() > 0.02 {
            return Err(format!("flip rate {flipped} vs p={p}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_streams_disjoint_across_clients_and_rounds() {
    check("stream_disjoint", 20, |rng| {
        let mut root = Rng::new(rng.next_u64());
        let a: Vec<u64> = {
            let mut r = root.fork(1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = root.fork(2);
            (0..16).map(|_| r.next_u64()).collect()
        };
        if a == b {
            return Err("forked streams identical".into());
        }
        Ok(())
    });
}
