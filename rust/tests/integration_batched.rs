//! Mega-batched dispatch (DESIGN.md §15): the cohort-batched executable
//! family must be BIT-IDENTICAL to the per-client path — first at
//! runtime level (one local round + one sketch, full and tail-padded
//! groups), then at full engine level (a seeded 5-round pFed1BS run at
//! `device_batch` ∈ {1, 4, 8} reproduces identical per-round losses,
//! personalized models, and consensus words).
//!
//! Requires `make artifacts` with the batched families in the manifest
//! (skips gracefully otherwise — e.g. against pre-batch artifacts).

use pfed1bs::algorithms;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;
use pfed1bs::runtime::Runtime;
use pfed1bs::sketch::SrhtOperator;
use pfed1bs::util::rng::Rng;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

/// Widths this suite exercises: B=4 (full groups) and B=8 (padded tail).
fn batched_families_built(widths: &[usize]) -> bool {
    widths.contains(&4) && widths.contains(&8)
}

/// Runtime-level parity: B lanes through one batched dispatch chain vs B
/// independent `client_round` calls, with distinct per-lane weights,
/// sketches, and data tiles. Covers a full group (4-of-4) and a padded
/// tail (5-of-8, where lanes 5..8 are replicated ballast whose outputs
/// are discarded).
#[test]
fn batched_round_and_sketch_bit_identical_to_per_client() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::new("artifacts").expect("runtime");
    let widths = rt.manifest.batch_sizes("mlp784");
    if !batched_families_built(&widths) {
        eprintln!("skipping: batched artifact families not built (got {widths:?})");
        return;
    }
    let info = rt.manifest.get("client_step", "mlp784").unwrap();
    let op = SrhtOperator::from_seed(7, info.n, info.m);
    let model = rt.model("mlp784", &op).expect("per-client model");

    for (bw, lanes) in [(4usize, 4usize), (8, 5)] {
        let bmodel = rt.model_with_batch("mlp784", &op, bw).expect("batched model");
        assert_eq!(bmodel.device_batch(), bw);
        let g = model.geom;
        let mut rng = Rng::new(99 + bw as u64);
        let ws: Vec<Vec<f32>> = (0..lanes)
            .map(|_| (0..g.n).map(|_| 0.1 * rng.normal()).collect())
            .collect();
        let vs: Vec<Vec<f32>> = (0..lanes)
            .map(|_| {
                (0..g.m)
                    .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let data: Vec<(Vec<f32>, Vec<i32>)> = (0..lanes)
            .map(|_| {
                (
                    (0..g.train_batch * g.input_dim).map(|_| rng.normal()).collect(),
                    (0..g.train_batch).map(|_| rng.below(g.classes) as i32).collect(),
                )
            })
            .collect();
        let r_steps = 3;

        let mut want = Vec::new();
        for lane in 0..lanes {
            let (w, loss) = model
                .client_round(
                    &ws[lane],
                    || (data[lane].0.clone(), data[lane].1.clone()),
                    r_steps,
                    &vs[lane],
                    0.05,
                    5e-4,
                    1e-5,
                    1e4,
                )
                .unwrap();
            let z = model.sketch_sign_packed(&w).unwrap();
            want.push((w, loss, z));
        }

        let w_refs: Vec<&[f32]> = ws.iter().map(|w| &w[..]).collect();
        let v_refs: Vec<&[f32]> = vs.iter().map(|v| &v[..]).collect();
        let got = bmodel
            .client_round_batched(
                &w_refs,
                &v_refs,
                |lane| (data[lane].0.clone(), data[lane].1.clone()),
                r_steps,
                0.05,
                5e-4,
                1e-5,
                1e4,
            )
            .unwrap();
        assert_eq!(got.len(), lanes);
        let updated: Vec<&[f32]> = got.iter().map(|(w, _)| &w[..]).collect();
        let zs = bmodel.sketch_sign_batched_packed(&updated).unwrap();
        assert_eq!(zs.len(), lanes);

        for lane in 0..lanes {
            let (want_w, want_loss, want_z) = &want[lane];
            let (got_w, got_loss) = &got[lane];
            assert_eq!(got_w.len(), want_w.len());
            for i in 0..got_w.len() {
                assert_eq!(
                    got_w[i].to_bits(),
                    want_w[i].to_bits(),
                    "B={bw} lane {lane} w[{i}]"
                );
            }
            assert_eq!(got_loss.to_bits(), want_loss.to_bits(), "B={bw} lane {lane} loss");
            assert_eq!(zs[lane].words(), want_z.words(), "B={bw} lane {lane} sketch words");
        }
    }
}

/// Engine-level golden equivalence: the same seeded 5-round pFed1BS run
/// at `device_batch` 1 (today's per-client path, byte-for-byte) vs 4 and
/// 8 must produce identical per-round train losses, final accuracy,
/// personalized model snapshots, and consensus words. participating=20
/// at B=4 packs five full groups; participating=5 at B=8 drives a
/// single tail-padded 5-of-8 dispatch every round.
#[test]
fn five_round_run_identical_across_device_batch() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let widths = lab.runtime.manifest.batch_sizes("mlp784");
    if !batched_families_built(&widths) {
        eprintln!("skipping: batched artifact families not built (got {widths:?})");
        return;
    }

    for (participating, batches) in [(20usize, &[1usize, 4][..]), (5, &[1, 4, 8][..])] {
        let mut snaps = Vec::new();
        for &db in batches {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.algorithm = "pfed1bs".to_string();
            cfg.rounds = 5;
            cfg.local_steps = 5;
            cfg.eval_every = 3;
            cfg.seed = 1234;
            cfg.participating = participating;
            cfg.device_batch = db;
            cfg.validate().unwrap();
            let model = lab.model_for(&cfg).unwrap();
            assert_eq!(model.device_batch(), if db > 1 { db } else { 1 });
            let mut alg = algorithms::build("pfed1bs").unwrap();
            let mut coord = Coordinator::new(cfg, &model);
            let result = coord.run(alg.as_mut()).unwrap();
            let losses: Vec<u64> = result
                .history
                .records
                .iter()
                .map(|r| r.train_loss.to_bits())
                .collect();
            let consensus = alg
                .consensus_packed()
                .expect("pfed1bs exposes its packed consensus")
                .words()
                .to_vec();
            snaps.push((losses, result.final_accuracy, alg.snapshot(), consensus));
        }
        for (i, snap) in snaps.iter().enumerate().skip(1) {
            let db = batches[i];
            assert_eq!(
                snaps[0].0, snap.0,
                "S={participating}: per-round losses diverged at device_batch={db}"
            );
            assert_eq!(
                snaps[0].1, snap.1,
                "S={participating}: final accuracy diverged at device_batch={db}"
            );
            assert_eq!(
                snaps[0].2, snap.2,
                "S={participating}: personalized models diverged at device_batch={db}"
            );
            assert_eq!(
                snaps[0].3, snap.3,
                "S={participating}: consensus words diverged at device_batch={db}"
            );
        }
    }
}
