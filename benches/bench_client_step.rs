//! Hot-path PJRT execute latency: client_step (task grad + fused SRHT
//! regularizer), sgd_step (task grad only — the FHT-free control),
//! sketch, and eval, per model variant. The client_step − sgd_step gap is
//! the price of the paper's regularizer; the sketch row is one forward
//! butterfly. Feeds EXPERIMENTS.md §Perf.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::runtime::Runtime;
use pfed1bs::sketch::SrhtOperator;
use pfed1bs::util::rng::Rng;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping bench_client_step: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").expect("runtime");
    let mut b = Bench::new("client_step");
    // cargo bench passes `--bench`; keep only bare variant names
    let variants: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let variants = if variants.is_empty() {
        vec!["mlp784".to_string(), "mlp3072".to_string()]
    } else {
        variants
    };

    for variant in &variants {
        let info = rt.manifest.get("client_step", variant).expect("manifest");
        let op = SrhtOperator::from_seed(7, info.n, info.m);
        let model = rt.model(variant, &op).expect("model");
        let g = model.geom;
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..g.n).map(|_| 0.1 * rng.normal()).collect();
        let x: Vec<f32> = (0..g.train_batch * g.input_dim).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..g.train_batch).map(|_| rng.below(g.classes) as i32).collect();
        let xe: Vec<f32> = (0..g.eval_batch * g.input_dim).map(|_| rng.normal()).collect();
        let ye: Vec<i32> = (0..g.eval_batch).map(|_| rng.below(g.classes) as i32).collect();
        let v = vec![1.0f32; g.m];

        b.bench(&format!("{variant}/client_step"), || {
            black_box(
                model
                    .client_step(&w, &x, &y, &v, 0.05, 5e-4, 1e-5, 1e4)
                    .unwrap(),
            );
        });
        b.bench(&format!("{variant}/sgd_step"), || {
            black_box(model.sgd_step(&w, &x, &y, 0.05, 1e-5).unwrap());
        });
        b.bench(&format!("{variant}/sketch"), || {
            black_box(model.sketch_sign(&w).unwrap());
        });
        b.bench(&format!("{variant}/eval_batch"), || {
            black_box(model.eval_batch(&w, &xe, &ye).unwrap());
        });
        b.bench(&format!("{variant}/grad_norm"), || {
            black_box(model.grad_norm(&w, &x, &y, &v, 5e-4, 1e-5, 1e4).unwrap());
        });

        // §Perf before/after: per-step cost when w stays device-resident
        // across R=10 steps (client_round) vs the host-round-trip path
        // (client_step called 10 times is the row above × 10).
        b.bench(&format!("{variant}/client_round_R10 (per-round)"), || {
            black_box(
                model
                    .client_round(
                        &w,
                        || (x.clone(), y.clone()),
                        10,
                        &v,
                        0.05,
                        5e-4,
                        1e-5,
                        1e4,
                    )
                    .unwrap(),
            );
        });
        b.bench(&format!("{variant}/sgd_round_R10 (per-round)"), || {
            black_box(
                model
                    .sgd_round(&w, || (x.clone(), y.clone()), 10, 0.05, 1e-5)
                    .unwrap(),
            );
        });

        // Mega-batched dispatch (DESIGN.md §15): one device execute
        // advances B clients per local step. The B=1 row anchors on the
        // unbatched client_round; throughput is client-steps/sec, so
        // the B→1 dispatch reduction shows up directly across rows.
        b.bench_elems(&format!("{variant}/batched_B1_R10 (per-round)"), 10, || {
            black_box(
                model
                    .client_round(
                        &w,
                        || (x.clone(), y.clone()),
                        10,
                        &v,
                        0.05,
                        5e-4,
                        1e-5,
                        1e4,
                    )
                    .unwrap(),
            );
        });
        for bw in [8usize, 32, 64] {
            if !rt.manifest.batch_sizes(variant).contains(&bw) {
                eprintln!(
                    "skipping {variant}/batched_B{bw}: manifest has no batch={bw} family \
                     (re-run `make artifacts`)"
                );
                continue;
            }
            let bmodel = rt.model_with_batch(variant, &op, bw).expect("batched model");
            let ws: Vec<&[f32]> = vec![&w[..]; bw];
            let vs: Vec<&[f32]> = vec![&v[..]; bw];
            b.bench_elems(
                &format!("{variant}/batched_B{bw}_R10 (per-round)"),
                (bw * 10) as u64,
                || {
                    black_box(
                        bmodel
                            .client_round_batched(
                                &ws,
                                &vs,
                                |_| (x.clone(), y.clone()),
                                10,
                                0.05,
                                5e-4,
                                1e-5,
                                1e4,
                            )
                            .unwrap(),
                    );
                },
            );
        }
    }
    b.report();
    b.emit_json("client_step");
}
