//! End-to-end round latency per algorithm (the Table-2 wall-clock story):
//! one full communication round — downlink, R local steps × S clients on
//! the PJRT runtime, compression, uplink, server aggregation — measured
//! through the real coordinator path.

use pfed1bs::algorithms::{self, Ctx};
use pfed1bs::bench_harness::Bench;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;
use pfed1bs::util::rng::Rng;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping bench_round: run `make artifacts` first");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut b = Bench::new("round");
    // measure few iterations — a round is 100s of ms
    b.measure = std::time::Duration::from_secs(4);
    b.warmup = std::time::Duration::from_millis(500);

    for alg_name in ["pfed1bs", "fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat"] {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.algorithm = alg_name.to_string();
        cfg.local_steps = 5;
        let model = lab.model_for(&cfg).expect("model");
        let mut alg = algorithms::build(alg_name).expect("alg");
        let mut coord = Coordinator::new(cfg.clone(), &model);
        let mut rng = Rng::new(1);
        {
            let mut ctx = Ctx {
                model: coord.model,
                data: &coord.data,
                cfg: &coord.cfg,
                net: &mut coord.net,
                rng: &mut rng,
                projection: &coord.projection,
            };
            alg.init(&mut ctx).expect("init");
        }
        let selected: Vec<usize> = (0..cfg.participating).collect();
        let weights = vec![1.0f32 / cfg.participating as f32; cfg.participating];
        let mut t = 0usize;
        b.bench(&format!("{alg_name}/round(S=20,R=5)"), || {
            let mut ctx = Ctx {
                model: coord.model,
                data: &coord.data,
                cfg: &coord.cfg,
                net: &mut coord.net,
                rng: &mut rng,
                projection: &coord.projection,
            };
            alg.round(t, &selected, &weights, &mut ctx).expect("round");
            coord.net.end_round();
            t += 1;
        });
    }
    b.report();
}
