//! End-to-end round latency per algorithm (the Table-2 wall-clock story):
//! one full communication round — downlink, R local steps × S clients on
//! the PJRT runtime, compression, uplink, server aggregation — measured
//! through the real coordinator protocol path.
//!
//! The client phase is the scaling surface: the pfed1bs/fedavg rows are
//! repeated across a thread sweep (1 / 2 / all cores) to show client-
//! phase wall-clock improving with thread count while staying
//! bit-identical (rust/tests/integration_training.rs asserts identity).

use pfed1bs::algorithms;
use pfed1bs::bench_harness::Bench;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping bench_round: run `make artifacts` first");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut b = Bench::new("round");
    // measure few iterations — a round is 100s of ms — but keep the
    // reduced budget in quick mode (the CI smoke run), which Bench::new
    // already configured
    if std::env::var("PFED1BS_BENCH_QUICK").is_err() {
        b.measure = std::time::Duration::from_secs(4);
        b.warmup = std::time::Duration::from_millis(500);
    }

    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut sweeps: Vec<usize> = vec![1, 2, cores];
    sweeps.sort_unstable();
    sweeps.dedup();

    for alg_name in ["pfed1bs", "fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat"] {
        // the two headline algorithms get the full thread sweep
        let threads: &[usize] = if alg_name == "pfed1bs" || alg_name == "fedavg" {
            &sweeps
        } else {
            &sweeps[..1]
        };
        for &nthreads in threads {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.algorithm = alg_name.to_string();
            cfg.local_steps = 5;
            cfg.client_threads = nthreads;
            let model = lab.model_for(&cfg).expect("model");
            let mut alg = algorithms::build(alg_name).expect("alg");
            let mut coord = Coordinator::new(cfg.clone(), &model);
            coord.init_algorithm(alg.as_mut()).expect("init");
            let selected: Vec<usize> = (0..cfg.participating).collect();
            let weights = vec![1.0f32 / cfg.participating as f32; cfg.participating];
            let mut t = 0usize;
            b.bench(&format!("{alg_name}/round(S=20,R=5,threads={nthreads})"), || {
                coord
                    .run_round(alg.as_mut(), t, &selected, &weights)
                    .expect("round");
                coord.net.end_round();
                t += 1;
            });
        }
    }
    b.report();
    b.emit_json("round");
}
