//! Hierarchical aggregation (DESIGN.md §11): flat single-aggregator
//! absorb vs E edge shards absorbing the same cohort and merging in
//! canonical edge order, plus the edge→root merge-frame codec cost.
//!
//! The edge rows measure the serial shape (one thread walks all shards)
//! so the numbers isolate the bookkeeping overhead of sharding — the
//! win in production is that the E absorb streams are independent
//! (shard-parallel by construction, exact by DESIGN.md §9); the
//! `sharded_merge` rows of bench_aggregate measure the same fold at
//! smaller K. Results land in `BENCH_topology.json`.

use pfed1bs::algorithms::{AggKind, ClientOutput, ClientStats, RoundAggregator, Uplink};
use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::comm::{decode, encode, Payload};
use pfed1bs::sketch::bitpack::{SignVec, VoteAccumulator};
use pfed1bs::util::rng::Rng;

fn outputs(rng: &mut Rng, k: usize, m: usize) -> Vec<ClientOutput> {
    (0..k)
        .map(|c| ClientOutput {
            client: c,
            uplink: Some(Uplink::new(
                0,
                Payload::Signs(SignVec::from_fn(m, |_| rng.f32() < 0.5)),
            )),
            state: None,
            stats: ClientStats { loss: 1.0 },
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("topology");
    let mut rng = Rng::new(11);

    for (k, m) in [(100usize, 10_177usize), (1000, 10_177)] {
        let cohort = outputs(&mut rng, k, m);
        let weights = vec![1.0f32 / k as f32; k];

        // the flat oracle: one aggregator, arrival order
        b.bench_elems(&format!("flat_absorb_K{k}_m{m}"), (k * m) as u64, || {
            let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)));
            for (out, &w) in cohort.iter().zip(&weights) {
                agg.absorb(black_box(out.clone()), w).unwrap();
            }
            black_box(agg.into_parts());
        });

        // client → edge → root: E shards absorb (k mod E assignment),
        // every edge ships its merge frame, the root merges in
        // canonical edge order — bit-identical to flat (prop_topology)
        for edges in [2usize, 4, 8, 16] {
            b.bench_elems(
                &format!("edge{edges}_absorb_merge_K{k}_m{m}"),
                (k * m) as u64,
                || {
                    let mut shards: Vec<RoundAggregator> = (0..edges)
                        .map(|_| RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m))))
                        .collect();
                    for (out, &w) in cohort.iter().zip(&weights) {
                        shards[out.client % edges]
                            .absorb(black_box(out.clone()), w)
                            .unwrap();
                    }
                    let mut it = shards.into_iter();
                    let mut root = it.next().unwrap();
                    for s in it {
                        root.merge(s).unwrap();
                    }
                    black_box(root.into_parts());
                },
            );
        }

    }

    // the edge→root wire: encode + decode one m-tally merge frame (cost
    // depends only on m, so this row lives outside the cohort loop)
    let m = 10_177usize;
    let shard = {
        let cohort = outputs(&mut rng, 100, m);
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)));
        for out in cohort {
            agg.absorb(out, 0.01).unwrap();
        }
        agg
    };
    let frame = shard.merge_payload().unwrap();
    b.bench_elems(&format!("tally_frame_codec_m{m}"), m as u64, || {
        black_box(decode(&encode(black_box(&frame))).unwrap());
    });

    b.report();
    b.emit_json("topology");
}
