//! Wire codec throughput: the encode/decode cost of each payload kind at
//! the sizes that cross the simulated network every round, verifying the
//! transport layer never becomes the L3 bottleneck.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::comm::{decode, encode, Payload};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("codec");
    let mut rng = Rng::new(9);

    let dense = Payload::Dense((0..101_770).map(|_| rng.normal()).collect());
    let signs = Payload::Signs(
        (0..10_177)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect(),
    );
    let scaled = Payload::ScaledSigns {
        signs: (0..101_770)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect(),
        scale: 0.01,
    };

    for (p, label, elems) in [
        (&dense, "dense_n101770", 101_770u64),
        (&signs, "signs_m10177", 10_177),
        (&scaled, "scaled_signs_n101770", 101_770),
    ] {
        let frame = encode(p);
        b.bench_elems(&format!("encode_{label}"), elems, || {
            black_box(encode(black_box(p)));
        });
        b.bench_elems(&format!("decode_{label}"), elems, || {
            black_box(decode(black_box(&frame)).unwrap());
        });
    }
    b.report();
}
