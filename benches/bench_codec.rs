//! Wire codec throughput: the encode/decode cost of each payload kind at
//! the sizes that cross the simulated network every round, verifying the
//! transport layer never becomes the L3 bottleneck. With payloads
//! carrying packed `SignVec`s, sign-frame encode/decode is a
//! near-memcpy of u64 words — the n-bit row (OBDA scale) makes that
//! visible next to the dense f32 row of the same element count.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::comm::{decode, encode, Payload};
use pfed1bs::sketch::bitpack::SignVec;
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("codec");
    let mut rng = Rng::new(9);

    let mut rand_signs = |n: usize| SignVec::from_fn(n, |_| rng.f32() < 0.5);

    let signs_m = Payload::Signs(rand_signs(10_177));
    let signs_n = Payload::Signs(rand_signs(101_770));
    let scaled = Payload::ScaledSigns { signs: rand_signs(101_770), scale: 0.01 };
    let dense = Payload::Dense((0..101_770).map(|_| rng.normal()).collect());

    for (p, label, elems) in [
        (&dense, "dense_n101770", 101_770u64),
        (&signs_m, "signs_m10177", 10_177),
        (&signs_n, "signs_n101770", 101_770),
        (&scaled, "scaled_signs_n101770", 101_770),
    ] {
        let frame = encode(p);
        b.bench_elems(&format!("encode_{label}"), elems, || {
            black_box(encode(black_box(p)));
        });
        b.bench_elems(&format!("decode_{label}"), elems, || {
            black_box(decode(black_box(&frame)).unwrap());
        });
        // zero-copy path: validate + view the same frame in place, no
        // word materialization (accepts/rejects identically to `decode`)
        b.bench_elems(&format!("decode_borrowed_{label}"), elems, || {
            black_box(Payload::decode_borrowed(black_box(&frame)).unwrap());
        });
    }
    b.report();
    b.emit_json("codec");
}
