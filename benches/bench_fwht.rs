//! FWHT / projection benchmark — the compute claim behind Appendix
//! Fig. 3 and the paper's "Efficient Projection" section: the structured
//! O(n log n) transform vs the O(mn) dense Gaussian projection, across
//! the sizes used by the model variants (2^17, 2^19) plus a sweep.
//!
//! Every transform size is measured twice — the planned blocked kernel
//! (`fwht_normalized`) next to the retained scalar reference
//! (`fwht::scalar`) — so one run prints this PR's before/after ratio;
//! the `*_threads*`/`batch` rows cover the worker-pool and stacked
//! modes, and the `srht_*` rows the fused end-to-end sketch pipeline.
//! `BENCH_fwht.json` carries the same rows machine-readably across PRs.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::sketch::fwht::scalar;
use pfed1bs::sketch::{
    fwht_batch, fwht_blocked_normalized_isa, fwht_normalized, fwht_threaded_normalized,
    DenseGaussianOperator, Isa, SrhtOperator,
};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("fwht_projection");
    let mut rng = Rng::new(7);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    // raw transform sweep: blocked kernel vs scalar reference
    for log2n in [10usize, 13, 16, 17, 19] {
        let n = 1usize << log2n;
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        b.bench_elems(&format!("fwht_2^{log2n}"), n as u64, || {
            fwht_normalized(black_box(&mut x));
        });
        b.bench_elems(&format!("fwht_scalar_2^{log2n}"), n as u64, || {
            scalar::fwht_normalized(black_box(&mut x));
        });
    }

    // explicit-ISA sweep at the headline size: the same blocked
    // schedule forced through each butterfly level this machine can
    // run (all bit-identical — only the wall clock may differ)
    let isas = Isa::available();
    {
        let n = 1usize << 17;
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for &isa in &isas {
            b.bench_elems(&format!("fwht_2^17_isa_{}", isa.name()), n as u64, || {
                fwht_blocked_normalized_isa(black_box(&mut x), isa);
            });
        }
    }

    // worker-pool mode at the model geometries (bit-identical to serial)
    let mut sweeps: Vec<usize> = vec![2, cores];
    sweeps.sort_unstable();
    sweeps.dedup();
    for log2n in [17usize, 19] {
        let n = 1usize << log2n;
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for &threads in &sweeps {
            b.bench_elems(&format!("fwht_2^{log2n}_threads{threads}"), n as u64, || {
                fwht_threaded_normalized(black_box(&mut x), threads);
            });
        }
    }

    // batched mode: B stacked vectors through one planned call
    {
        let (bsz, n) = (16usize, 1usize << 13);
        let mut xs: Vec<f32> = (0..bsz * n).map(|_| rng.normal()).collect();
        b.bench_elems(&format!("fwht_batch_B{bsz}_2^13"), (bsz * n) as u64, || {
            fwht_batch(black_box(&mut xs), n);
        });
    }

    // full SRHT sketch (pad + D + FWHT + subsample + sign, fully fused)
    // at the two model geometries, vs the dense Gaussian projection the
    // paper replaces (dense limited to a feasible size — it is O(mn))
    for (n, label) in [(101_770usize, "mlp784"), (453_682, "mlp3072")] {
        let m = n / 10;
        let op = SrhtOperator::from_seed(1, n, m);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        b.bench_elems(&format!("srht_sketch_{label}(n={n})"), n as u64, || {
            black_box(op.sketch_sign(black_box(&w)));
        });
        // the transport-ready path: SignVec words straight off the plan
        b.bench_elems(&format!("srht_sketch_packed_{label}"), n as u64, || {
            black_box(op.sketch_sign_packed(black_box(&w)));
        });
        // hoisted OUT of the timed closure: the old `vec![1.0; m]`
        // inside the body made this row measure allocator traffic
        let v: Vec<f32> = vec![1.0; m];
        b.bench_elems(&format!("srht_adjoint_{label}"), n as u64, || {
            black_box(op.adjoint(black_box(&v)));
        });
        b.bench_elems(&format!("srht_adjoint_threads{cores}_{label}"), n as u64, || {
            black_box(op.adjoint_threaded(black_box(&v), cores));
        });
    }

    // dense Gaussian at a reduced size to keep the bench finite; the
    // asymptotic O(mn) vs O(n log n) gap is the printed ratio
    let n_small = 16_384usize;
    let m_small = n_small / 10;
    let dense = DenseGaussianOperator::from_seed(2, n_small, m_small);
    let srht_small = SrhtOperator::from_seed(2, n_small, m_small);
    let w_small: Vec<f32> = (0..n_small).map(|_| rng.normal()).collect();
    let md = b
        .bench_elems(&format!("dense_gaussian_sketch(n={n_small})"), n_small as u64, || {
            black_box(dense.sketch_sign(black_box(&w_small)));
        })
        .mean_ns;
    let ms = b
        .bench_elems(&format!("srht_sketch(n={n_small})"), n_small as u64, || {
            black_box(srht_small.sketch_sign(black_box(&w_small)));
        })
        .mean_ns;

    b.report();

    // the PR-body ratio: blocked kernel vs scalar reference per size
    println!("\nblocked kernel vs scalar reference (same arithmetic, bit-identical):");
    let rows = b.results().to_vec();
    for log2n in [10usize, 13, 16, 17, 19] {
        let pick = |name: &str| rows.iter().find(|m| m.name == name).map(|m| m.mean_ns);
        if let (Some(new), Some(old)) = (
            pick(&format!("fwht_2^{log2n}")),
            pick(&format!("fwht_scalar_2^{log2n}")),
        ) {
            println!("  fwht_2^{log2n}: {:.2}x faster (scalar/blocked)", old / new);
        }
    }

    // the tentpole ratio: explicit SIMD butterflies vs the forced-scalar
    // level under the identical blocked schedule
    let pick = |name: String| rows.iter().find(|m| m.name == name).map(|m| m.mean_ns);
    if let Some(scalar_ns) = pick("fwht_2^17_isa_scalar".to_string()) {
        for &isa in &isas {
            if isa == Isa::Scalar {
                continue;
            }
            if let Some(simd_ns) = pick(format!("fwht_2^17_isa_{}", isa.name())) {
                println!(
                    "simd vs scalar at 2^17: {} is {:.2}x faster (scalar/{})",
                    isa.name(),
                    scalar_ns / simd_ns,
                    isa.name()
                );
            }
        }
    }
    println!(
        "\ndense/srht ratio at n={n_small}: {:.1}x (theory m/log2(n') = {:.1}x)",
        md / ms,
        (m_small as f64) / (n_small as f64).log2()
    );
    b.emit_json("fwht");
}
