//! FWHT / projection benchmark — the compute claim behind Appendix
//! Fig. 3 and the paper's "Efficient Projection" section: the structured
//! O(n log n) transform vs the O(mn) dense Gaussian projection, across
//! the sizes used by the model variants (2^17, 2^19) plus a sweep.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::sketch::{fwht_normalized, DenseGaussianOperator, SrhtOperator};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("fwht_projection");
    let mut rng = Rng::new(7);

    // raw transform sweep
    for log2n in [10usize, 13, 16, 17, 19] {
        let n = 1usize << log2n;
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        b.bench_elems(&format!("fwht_2^{log2n}"), n as u64, || {
            fwht_normalized(black_box(&mut x));
        });
    }

    // full SRHT sketch (pad + D + FWHT + subsample + sign) at the two
    // model geometries, vs the dense Gaussian projection the paper
    // replaces (dense limited to a feasible size — it is O(mn))
    for (n, label) in [(101_770usize, "mlp784"), (453_682, "mlp3072")] {
        let m = n / 10;
        let op = SrhtOperator::from_seed(1, n, m);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        b.bench_elems(&format!("srht_sketch_{label}(n={n})"), n as u64, || {
            black_box(op.sketch_sign(black_box(&w)));
        });
        b.bench_elems(&format!("srht_adjoint_{label}"), n as u64, || {
            let v: Vec<f32> = vec![1.0; m];
            black_box(op.adjoint(black_box(&v)));
        });
    }

    // dense Gaussian at a reduced size to keep the bench finite; the
    // asymptotic O(mn) vs O(n log n) gap is the printed ratio
    let n_small = 16_384usize;
    let m_small = n_small / 10;
    let dense = DenseGaussianOperator::from_seed(2, n_small, m_small);
    let srht_small = SrhtOperator::from_seed(2, n_small, m_small);
    let w_small: Vec<f32> = (0..n_small).map(|_| rng.normal()).collect();
    let md = b
        .bench_elems(&format!("dense_gaussian_sketch(n={n_small})"), n_small as u64, || {
            black_box(dense.sketch_sign(black_box(&w_small)));
        })
        .mean_ns;
    let ms = b
        .bench_elems(&format!("srht_sketch(n={n_small})"), n_small as u64, || {
            black_box(srht_small.sketch_sign(black_box(&w_small)));
        })
        .mean_ns;

    b.report();
    println!(
        "\ndense/srht ratio at n={n_small}: {:.1}x (theory m/log2(n') = {:.1}x)",
        md / ms,
        (m_small as f64) / (n_small as f64).log2()
    );
}
