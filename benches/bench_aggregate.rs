//! Server aggregation (Lemma 1 majority vote): weighted vs uniform-
//! popcount paths across client counts — the L3 hot loop that closes
//! every round. K=20 × m=10,177 is the paper's MNIST configuration.
//!
//! The `*_packed` rows vote directly over borrowed `SignVec` words — no
//! unpack/re-pack round trip anywhere. The `*_repack` row reproduces the
//! pre-SignVec server path (uplinks decoded to f32 ±1 lanes, re-packed
//! from scratch before the vote) so the saving stays measurable.
//!
//! The `streaming_absorb` rows are the event engine's actual server
//! path: one O(m) `VoteAccumulator`, each sketch folded on arrival —
//! O(m) state however large K grows, vs the batch rows' O(K·m) resident
//! cohort. The `sharded_merge` rows split the same fold across 1/4/16
//! shards and merge (exact), the shape a shard-parallel server takes.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::sketch::bitpack::{
    majority_vote_uniform, majority_vote_weighted, GroupedTally, SignVec, VoteAccumulator,
};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("aggregate");
    let mut rng = Rng::new(5);

    for (k, m) in [(20usize, 10_177usize), (20, 45_368), (100, 10_177), (5, 10_177)] {
        let lanes: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                (0..m)
                    .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                    .collect()
            })
            .collect();
        let sketches: Vec<SignVec> = lanes.iter().map(|z| SignVec::from_signs(z)).collect();
        let borrowed: Vec<&SignVec> = sketches.iter().collect();
        let weights = vec![1.0f32 / k as f32; k];

        // packed end-to-end: borrow the delivered words, vote, done —
        // the batch reference the streaming tally is tested against
        b.bench_elems(&format!("weighted_vote_packed_K{k}_m{m}"), (k * m) as u64, || {
            black_box(majority_vote_weighted(
                black_box(&borrowed),
                black_box(&weights),
                m,
            ));
        });
        b.bench_elems(&format!("uniform_vote_packed_K{k}_m{m}"), (k * m) as u64, || {
            black_box(majority_vote_uniform(black_box(&borrowed), m));
        });

        // the old server path: re-pack every client's f32 lanes each
        // round before voting (kept as the baseline being beaten)
        b.bench_elems(&format!("weighted_vote_repack_K{k}_m{m}"), (k * m) as u64, || {
            let packed: Vec<SignVec> =
                black_box(&lanes).iter().map(|z| SignVec::from_signs(z)).collect();
            black_box(majority_vote_weighted(&packed, black_box(&weights), m));
        });

        // the streaming server: absorb each delivered sketch into one
        // O(m) tally, then sign it — what run_round_plan actually does
        b.bench_elems(&format!("streaming_absorb_K{k}_m{m}"), (k * m) as u64, || {
            let mut acc = VoteAccumulator::new(m);
            for (z, &p) in sketches.iter().zip(&weights) {
                acc.absorb(black_box(z), p as f64);
            }
            black_box(acc.finish());
        });

        // shard-parallel fold shape: S independent shards, merged in
        // canonical shard order (exact — bit-identical to 1 shard)
        for shards in [1usize, 4, 16] {
            b.bench_elems(
                &format!("sharded_merge_S{shards}_K{k}_m{m}"),
                (k * m) as u64,
                || {
                    let mut parts: Vec<VoteAccumulator> =
                        (0..shards).map(|_| VoteAccumulator::new(m)).collect();
                    for (i, (z, &p)) in sketches.iter().zip(&weights).enumerate() {
                        parts[i % shards].absorb(black_box(z), p as f64);
                    }
                    let mut acc = parts.remove(0);
                    for part in parts {
                        acc.merge(part);
                    }
                    black_box(acc.finish());
                },
            );
        }

        // robust tallies (DESIGN.md §16): per-client buckets absorbed
        // then both tails trimmed coordinate-wise before the sign —
        // O(K·m) state vs the plain vote's O(m), priced here
        b.bench_elems(&format!("trimmed_absorb_K{k}_m{m}"), (k * m) as u64, || {
            let mut tally = GroupedTally::new(m, k);
            for (i, (z, &p)) in sketches.iter().zip(&weights).enumerate() {
                tally.absorb(i, black_box(z), p as f64);
            }
            black_box(tally.finish_trimmed(0.2));
        });

        // median-of-means: 5 group buckets folded on 4 shards, merged
        // in canonical order, coordinate-wise median of group means
        b.bench_elems(&format!("mom_merge_K{k}_m{m}"), (k * m) as u64, || {
            let shards = 4usize;
            let mut parts: Vec<GroupedTally> =
                (0..shards).map(|_| GroupedTally::new(m, 5)).collect();
            for (i, (z, &p)) in sketches.iter().zip(&weights).enumerate() {
                parts[i % shards].absorb(i, black_box(z), p as f64);
            }
            let mut tally = parts.remove(0);
            for part in parts {
                tally.merge(part);
            }
            black_box(tally.finish_median());
        });
    }
    b.report();
    b.emit_json("aggregate");
}
