//! Server aggregation (Lemma 1 majority vote): weighted vs uniform-
//! popcount paths across client counts — the L3 hot loop that closes
//! every round. K=20 × m=10,177 is the paper's MNIST configuration.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::sketch::bitpack::{majority_vote_uniform, majority_vote_weighted, pack_signs};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("aggregate");
    let mut rng = Rng::new(5);

    for (k, m) in [(20usize, 10_177usize), (20, 45_368), (100, 10_177), (5, 10_177)] {
        let sketches: Vec<Vec<u64>> = (0..k)
            .map(|_| {
                let signs: Vec<f32> = (0..m)
                    .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                    .collect();
                pack_signs(&signs)
            })
            .collect();
        let weights = vec![1.0f32 / k as f32; k];
        b.bench_elems(&format!("weighted_vote_K{k}_m{m}"), (k * m) as u64, || {
            black_box(majority_vote_weighted(
                black_box(&sketches),
                black_box(&weights),
                m,
            ));
        });
        b.bench_elems(&format!("uniform_vote_K{k}_m{m}"), (k * m) as u64, || {
            black_box(majority_vote_uniform(black_box(&sketches), m));
        });
    }
    b.report();
}
