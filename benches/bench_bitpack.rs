//! One-bit transport primitives: pack/unpack at the sketch sizes each
//! model variant ships per round (m = 10,177 / 45,368) and at the n-bit
//! sizes the OBDA-style baselines ship, plus the packed-word paths that
//! stay in `SignVec` form end-to-end (hamming popcount, masked-XOR bit
//! flips) and never touch f32 lanes.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::sketch::bitpack::{pack_signs, unpack_signs, SignVec};
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("bitpack");
    let mut rng = Rng::new(3);

    for (m, label) in [
        (10_177usize, "m_mlp784"),
        (45_368, "m_mlp3072"),
        (101_770, "n_mlp784"),
        (453_682, "n_mlp3072"),
    ] {
        let signs: Vec<f32> = (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let packed = pack_signs(&signs);
        b.bench_elems(&format!("pack_{label}({m})"), m as u64, || {
            black_box(pack_signs(black_box(&signs)));
        });
        b.bench_elems(&format!("unpack_{label}({m})"), m as u64, || {
            black_box(unpack_signs(black_box(&packed), m));
        });

        // packed-only paths: no f32 lane materialization anywhere
        let a = SignVec::from_signs(&signs);
        let mut c = a.clone();
        c.flip_bits_where(|i| i % 7 == 0);
        b.bench_elems(&format!("hamming_{label}({m})"), m as u64, || {
            black_box(black_box(&a).hamming(black_box(&c)));
        });
        b.bench_elems(&format!("flip_mask_{label}({m})"), m as u64, || {
            // the SimNetwork corruption shape: one predicate per live
            // bit, folded into per-word XOR masks
            c.flip_bits_where(|i| i % 13 == 0);
            black_box(&c);
        });
    }
    b.report();
    b.emit_json("bitpack");
}
