//! Socket-transport overhead: what the envelope framing and a real
//! loopback round trip cost next to the raw codec (bench_codec.rs). The
//! envelope adds 9 bytes + one length prefix per frame, so encode/decode
//! should stay a near-memcpy of the codec frame; the loopback row prices
//! the full OS-socket round trip (write + kernel + read + decode) that
//! `StreamTransport` pays per uplink — the number that bounds single-
//! connection rounds/sec for `pfed1bs serve`.

use pfed1bs::bench_harness::{black_box, Bench};
use pfed1bs::comm::codec::{Payload, TallyFrame};
use pfed1bs::comm::transport::frame::{decode_body, decode_body_borrowed, encode_body, Frame};
use pfed1bs::comm::{StreamTransport, Transport, Tuning};
use pfed1bs::sketch::bitpack::SignVec;
use pfed1bs::util::rng::Rng;

fn main() {
    let mut b = Bench::new("transport");
    let mut rng = Rng::new(11);
    let m = 10_177usize;

    let signs = SignVec::from_fn(m, |_| rng.f32() < 0.5);
    let uplink = Frame::Uplink { round: 3, client: 7, payload: Payload::Signs(signs.clone()) };
    let tally = Frame::Tally {
        round: 3,
        edge: 1,
        payload: Payload::TallyFrame(TallyFrame {
            absorbed: 16,
            loss_sum: 1.5,
            scalar: 0,
            quanta: (0..m).map(|_| rng.next_u64() as i128).collect(),
            groups: Vec::new(),
        }),
    };

    for (f, label) in [(&uplink, "uplink_m10177"), (&tally, "tally_m10177")] {
        let body = encode_body(f);
        b.bench_elems(&format!("encode_{label}"), m as u64, || {
            black_box(encode_body(black_box(f)));
        });
        b.bench_elems(&format!("decode_{label}"), m as u64, || {
            black_box(decode_body(black_box(&body)).unwrap());
        });
        // zero-copy envelope parse: what the serve reader loops pay per
        // received body (no payload word materialization)
        b.bench_elems(&format!("decode_borrowed_{label}"), m as u64, || {
            black_box(decode_body_borrowed(black_box(&body)).unwrap());
        });
    }

    // the full loopback round trip StreamTransport pays per uplink
    let mut net = StreamTransport::loopback(11, &Tuning::default()).expect("loopback");
    let payload = Payload::Signs(signs);
    b.bench_elems("loopback_uplink_m10177", m as u64, || {
        black_box(net.uplink_from(0, black_box(&payload)).unwrap());
    });
    net.end_round();

    b.report();
    b.emit_json("transport");
}
