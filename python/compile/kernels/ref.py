"""Pure-jnp reference oracle for the SRHT one-bit sketching operators.

Everything in this file is the *specification*: the Pallas kernels in
``fht.py`` and the rust mirror in ``rust/src/sketch/`` are both tested
against these functions. Keep this file boring and obviously-correct.

The operator (paper, "Efficient Projection via Fast Hadamard Transform"):

    Phi = sqrt(n'/m) * S * H * D * P_pad          (Eq. 16)
    Phi^T v = P_trunc * D * H^T * S'^T * v        (Eq. 18)

with H the *normalized* Walsh-Hadamard matrix (H H^T = I), D a diagonal
+-1 sign matrix, S a row-subsampling matrix selecting m of n' rows, and
P_pad zero-padding from n to n' = 2^ceil(log2 n).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def next_pow2(n: int) -> int:
    """Smallest power of two >= n."""
    return 1 << max(0, (n - 1).bit_length())


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized fast Walsh-Hadamard transform of a power-of-two vector.

    Iterative butterfly in natural (Hadamard) order:
    stage s pairs elements at stride 2^s. Output equals ``H_norm @ x``
    where ``H_norm = H / sqrt(n)`` and H is the +-1 Sylvester Hadamard
    matrix.
    """
    n = x.shape[0]
    assert n & (n - 1) == 0, f"fwht needs power-of-two length, got {n}"
    log2n = n.bit_length() - 1
    h = 1
    for _ in range(log2n):
        x = x.reshape(-1, 2, h)
        a = x[:, 0, :]
        b = x[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    return x.reshape(n) * jnp.asarray(2.0 ** (-log2n / 2), x.dtype)


def hadamard_dense(n: int) -> np.ndarray:
    """Dense normalized Sylvester-Hadamard matrix (tests only; O(n^2))."""
    assert n & (n - 1) == 0
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h / math.sqrt(n)


def srht_forward_ref(
    w: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray
) -> jnp.ndarray:
    """z = Phi w = sqrt(n'/m) * S H D pad(w)   (real-valued sketch, Eq. 16)."""
    n = w.shape[0]
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    wpad = jnp.zeros((nprime,), w.dtype).at[:n].set(w)
    y = fwht_ref(wpad * dsign)
    scale = jnp.asarray(math.sqrt(nprime / m), w.dtype)
    return y[sidx] * scale


def srht_adjoint_ref(
    v: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray, n: int
) -> jnp.ndarray:
    """g = Phi^T v = P_trunc D H^T S'^T v   (Eq. 18).  H^T = H (symmetric)."""
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    scale = jnp.asarray(math.sqrt(nprime / m), v.dtype)
    lifted = jnp.zeros((nprime,), v.dtype).at[sidx].set(v * scale)
    return (fwht_ref(lifted) * dsign)[:n]


def sketch_sign_ref(
    w: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray
) -> jnp.ndarray:
    """One-bit sketch z = sign(Phi w), ties broken to +1 (sign(0)=+1)."""
    z = srht_forward_ref(w, dsign, sidx)
    return jnp.where(z >= 0, 1.0, -1.0).astype(w.dtype)


def reg_grad_ref(
    w: jnp.ndarray,
    v: jnp.ndarray,
    dsign: jnp.ndarray,
    sidx: jnp.ndarray,
    gamma,
) -> jnp.ndarray:
    """Gradient of the smoothed sign regularizer (paper Eq. 7):

        grad g~(v, Phi w) = Phi^T ( tanh(gamma * Phi w) - v )
    """
    z = srht_forward_ref(w, dsign, sidx)
    r = jnp.tanh(gamma * z) - v
    return srht_adjoint_ref(r, dsign, sidx, w.shape[0])


def reg_value_ref(
    w: jnp.ndarray,
    v: jnp.ndarray,
    dsign: jnp.ndarray,
    sidx: jnp.ndarray,
    gamma,
) -> jnp.ndarray:
    """Smoothed regularizer value (paper Eq. 5):

        g~(v, Phi w) = h_gamma(Phi w) - <v, Phi w>,
        h_gamma(z)   = (1/gamma) * sum_i log cosh(gamma z_i)

    log cosh is computed stably as |t| + log1p(exp(-2|t|)) - log 2.
    """
    z = srht_forward_ref(w, dsign, sidx)
    t = gamma * z
    at = jnp.abs(t)
    logcosh = at + jnp.log1p(jnp.exp(-2.0 * at)) - jnp.log(2.0)
    return jnp.sum(logcosh) / gamma - jnp.dot(v, z)
