"""L1 Pallas kernels: SRHT one-bit sketching hot path.

The compute hot-spot of pFed1BS is the structured projection

    Phi w       = sqrt(n'/m) * S * H * D * pad(w)        (paper Eq. 16)
    Phi^T v     = P_trunc * D * H * S'^T * v             (paper Eq. 18)
    grad g~     = Phi^T ( tanh(gamma * Phi w) - v )      (paper Eq.  7)

implemented here as Pallas kernels so that the whole pipeline — sign flip
(D), the log2(n') butterfly stages of the Fast Hadamard Transform (H),
subsampling (S), and the tanh/sign nonlinearity — runs as ONE fused pass
over a VMEM-resident buffer.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the padded vector
(n' <= 2^20, 4 MiB f32) fits VMEM whole, so every butterfly stage is a
lane-aligned vadd/vsub over the same buffer with no HBM round trips; the
diagonal D fuses into stage 0 and the subsample gather + sign fuse into
the final store. ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so these kernels lower to plain HLO — the
*structure* (single fused pass, static butterfly schedule) is what the
AOT artifact inherits.

All kernels are shape-polymorphic at trace time only: n, n', m are fixed
per model variant when ``aot.py`` lowers the artifacts.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fwht_pallas",
    "srht_forward_pallas",
    "srht_adjoint_pallas",
    "sketch_sign_pallas",
    "reg_grad_pallas",
]


def _pad(w: jnp.ndarray, nprime: int) -> jnp.ndarray:
    """Zero-pad to n'; no-op when n is already a power of two (avoids a
    zero-length captured constant under pallas tracing)."""
    n = w.shape[0]
    if n == nprime:
        return w
    return jnp.zeros((nprime,), w.dtype).at[:n].set(w)


def _trunc(y: jnp.ndarray, n: int) -> jnp.ndarray:
    """First-n-coordinates truncation P_trunc; no-op when n == n'."""
    if y.shape[0] == n:
        return y
    return y[:n]


def _butterfly(x: jnp.ndarray, log2n: int) -> jnp.ndarray:
    """Unrolled normalized FWHT butterfly over a flat power-of-two vector.

    Stage s (h = 2^s) pairs lanes at stride h; each stage is one
    vadd/vsub pass over the VMEM-resident buffer. The reshape/stack here
    is how Mosaic expresses the sublane/lane shuffle — no data leaves the
    register/VMEM tile between stages.
    """
    n = x.shape[0]
    h = 1
    for _ in range(log2n):
        x = x.reshape(-1, 2, h)
        a = x[:, 0, :]
        b = x[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    return x.reshape(n) * jnp.asarray(2.0 ** (-log2n / 2), x.dtype)


# ---------------------------------------------------------------------------
# fwht


def _fwht_kernel(x_ref, o_ref, *, log2n: int):
    o_ref[...] = _butterfly(x_ref[...], log2n)


def fwht_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized FWHT of a power-of-two-length vector (Pallas, fused)."""
    n = x.shape[0]
    assert n & (n - 1) == 0, f"fwht needs power-of-two length, got {n}"
    log2n = n.bit_length() - 1
    return pl.pallas_call(
        functools.partial(_fwht_kernel, log2n=log2n),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# forward sketch  z = Phi w (real-valued)


def _srht_fwd_kernel(w_ref, d_ref, s_ref, o_ref, *, nprime: int, log2n: int, scale: float):
    w = w_ref[...]
    # pad -> sign flip (D fuses into the load of stage 0)
    x = _pad(w, nprime) * d_ref[...]
    y = _butterfly(x, log2n)
    # subsample gather + scaling fuse into the store
    o_ref[...] = jnp.take(y, s_ref[...], axis=0) * jnp.asarray(scale, w.dtype)


def srht_forward_pallas(
    w: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray
) -> jnp.ndarray:
    """z = Phi w = sqrt(n'/m) * S H D pad(w), one fused VMEM pass."""
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    log2n = nprime.bit_length() - 1
    return pl.pallas_call(
        functools.partial(
            _srht_fwd_kernel,
            nprime=nprime,
            log2n=log2n,
            scale=math.sqrt(nprime / m),
        ),
        out_shape=jax.ShapeDtypeStruct((m,), w.dtype),
        interpret=True,
    )(w, dsign, sidx)


# ---------------------------------------------------------------------------
# adjoint  g = Phi^T v


def _srht_adj_kernel(v_ref, d_ref, s_ref, o_ref, *, nprime: int, log2n: int, scale: float, n: int):
    v = v_ref[...]
    lifted = jnp.zeros((nprime,), v.dtype).at[s_ref[...]].set(
        v * jnp.asarray(scale, v.dtype)
    )
    y = _butterfly(lifted, log2n) * d_ref[...]
    o_ref[...] = _trunc(y, n)


def srht_adjoint_pallas(
    v: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray, n: int
) -> jnp.ndarray:
    """g = Phi^T v = P_trunc D H S'^T v, one fused VMEM pass (H^T = H)."""
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    log2n = nprime.bit_length() - 1
    return pl.pallas_call(
        functools.partial(
            _srht_adj_kernel,
            nprime=nprime,
            log2n=log2n,
            scale=math.sqrt(nprime / m),
            n=n,
        ),
        out_shape=jax.ShapeDtypeStruct((n,), v.dtype),
        interpret=True,
    )(v, dsign, sidx)


# ---------------------------------------------------------------------------
# one-bit sketch  z = sign(Phi w)


def _sketch_sign_kernel(w_ref, d_ref, s_ref, o_ref, *, nprime: int, log2n: int, scale: float):
    w = w_ref[...]
    x = _pad(w, nprime) * d_ref[...]
    y = _butterfly(x, log2n)
    z = jnp.take(y, s_ref[...], axis=0) * jnp.asarray(scale, w.dtype)
    # sign with sign(0) := +1, fused into the store
    o_ref[...] = jnp.where(z >= 0, 1.0, -1.0).astype(w.dtype)


def sketch_sign_pallas(
    w: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray
) -> jnp.ndarray:
    """One-bit sketch z = sign(Phi w) in {-1,+1}^m (f32; rust bit-packs)."""
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    log2n = nprime.bit_length() - 1
    return pl.pallas_call(
        functools.partial(
            _sketch_sign_kernel,
            nprime=nprime,
            log2n=log2n,
            scale=math.sqrt(nprime / m),
        ),
        out_shape=jax.ShapeDtypeStruct((m,), w.dtype),
        interpret=True,
    )(w, dsign, sidx)


# ---------------------------------------------------------------------------
# fused regularizer gradient  Phi^T (tanh(gamma Phi w) - v)


def _reg_grad_kernel(
    w_ref, v_ref, d_ref, s_ref, g_ref, o_ref, *, nprime: int, log2n: int, scale: float
):
    n = w_ref.shape[0]
    w = w_ref[...]
    d = d_ref[...]
    s = s_ref[...]
    gamma = g_ref[0]
    sc = jnp.asarray(scale, w.dtype)
    # forward: z = Phi w
    x = _pad(w, nprime) * d
    z = jnp.take(_butterfly(x, log2n), s, axis=0) * sc
    # residual in sketch space
    r = jnp.tanh(gamma * z) - v_ref[...]
    # adjoint: Phi^T r — reuses the same VMEM buffer shape
    lifted = jnp.zeros((nprime,), w.dtype).at[s].set(r * sc)
    o_ref[...] = _trunc(_butterfly(lifted, log2n) * d, n)


def reg_grad_pallas(
    w: jnp.ndarray,
    v: jnp.ndarray,
    dsign: jnp.ndarray,
    sidx: jnp.ndarray,
    gamma: jnp.ndarray,
) -> jnp.ndarray:
    """grad g~(v, Phi w) = Phi^T(tanh(gamma Phi w) - v)  (paper Eq. 7).

    Fully fused: forward butterfly, tanh residual, adjoint butterfly, and
    the D / S (un)shuffles run as one kernel so the n'-sized workspace is
    allocated once and never spills between the two transforms.

    ``gamma`` is a shape-(1,) f32 array so the lowered artifact keeps the
    smoothing temperature as a *runtime* parameter (sensitivity sweeps in
    Appendix Table 1 need no recompilation).
    """
    nprime = dsign.shape[0]
    m = sidx.shape[0]
    log2n = nprime.bit_length() - 1
    return pl.pallas_call(
        functools.partial(
            _reg_grad_kernel,
            nprime=nprime,
            log2n=log2n,
            scale=math.sqrt(nprime / m),
        ),
        out_shape=jax.ShapeDtypeStruct((w.shape[0],), w.dtype),
        interpret=True,
    )(w, v, dsign, sidx, gamma.reshape(1))
