"""AOT lowering driver: JAX -> HLO text artifacts for the rust runtime.

Emits, for every model variant in ``model.VARIANTS``, one HLO-text file
per artifact function plus a ``manifest.txt`` the rust side parses.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the rust side always unwraps a tuple. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Re-running is cheap: files are only rewritten when content changes, and
`make artifacts` skips the whole step when inputs are older than outputs.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``return_tuple=False`` is used for the single-output ``*_w`` step
    artifacts: a non-tuple root lets the rust runtime reuse the output
    device buffer directly as the next step's input (w stays device-
    resident across the whole local round — EXPERIMENTS.md §Perf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def write_if_changed(path: str, content: str) -> bool:
    if os.path.exists(path):
        with open(path) as f:
            if f.read() == content:
                return False
    with open(path, "w") as f:
        f.write(content)
    return True


def lower_variant(variant: model.ModelVariant, out_dir: str, manifest: list) -> None:
    shapes = model.example_shapes(variant)
    fns = model.artifact_fns(variant)
    for fn_name, args in shapes.items():
        lowered = jax.jit(fns[fn_name]).lower(*args)
        # *_w artifacts return one array and are lowered tuple-free
        text = to_hlo_text(lowered, return_tuple=not fn_name.endswith("_w"))
        fname = f"{fn_name}_{variant.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        changed = write_if_changed(path, text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest.append(
            dict(
                artifact=fn_name,
                variant=variant.name,
                file=fname,
                n=variant.n_params,
                npad=variant.n_pad,
                m=variant.sketch_dim,
                input_dim=variant.input_dim,
                classes=variant.classes,
                train_batch=model.TRAIN_BATCH,
                eval_batch=model.EVAL_BATCH,
                sha256=digest,
            )
        )
        status = "wrote" if changed else "unchanged"
        print(f"  {status} {fname} ({len(text)} chars)", file=sys.stderr)


def lower_variant_batched(
    variant: model.ModelVariant, b: int, out_dir: str, manifest: list
) -> None:
    """Lower the cohort-batched artifact family at batch width ``b``.

    Rows carry an extra ``batch=B`` key; unbatched rows keep the exact
    legacy key set so pre-batch manifest parsers stay compatible.
    """
    shapes = model.batched_shapes(variant, b)
    fns = model.batched_fns(variant)
    for fn_name, args in shapes.items():
        lowered = jax.jit(fns[fn_name]).lower(*args)
        # *_w artifacts return one array and are lowered tuple-free
        text = to_hlo_text(lowered, return_tuple=not fn_name.endswith("_w"))
        fname = f"{fn_name}_b{b}_{variant.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        changed = write_if_changed(path, text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest.append(
            dict(
                artifact=fn_name,
                variant=variant.name,
                file=fname,
                n=variant.n_params,
                npad=variant.n_pad,
                m=variant.sketch_dim,
                input_dim=variant.input_dim,
                classes=variant.classes,
                train_batch=model.TRAIN_BATCH,
                eval_batch=model.EVAL_BATCH,
                batch=b,
                sha256=digest,
            )
        )
        status = "wrote" if changed else "unchanged"
        print(f"  {status} {fname} ({len(text)} chars)", file=sys.stderr)


def format_manifest(entries: list) -> str:
    """Line-oriented ``key=value`` records; one artifact per line.

    Deliberately not JSON/TOML: the rust side has no serde, and this stays
    greppable. Field order is stable.
    """
    keys = [
        "artifact", "variant", "file", "n", "npad", "m",
        "input_dim", "classes", "train_batch", "eval_batch", "sha256",
    ]
    batched_keys = keys[:-1] + ["batch", "sha256"]
    lines = ["# pfed1bs artifact manifest v1"]
    for e in entries:
        ks = batched_keys if "batch" in e else keys
        lines.append(" ".join(f"{k}={e[k]}" for k in ks))
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: ignored single-file target")
    ap.add_argument(
        "--variants", default=",".join(model.VARIANTS), help="comma-separated subset"
    )
    ap.add_argument(
        "--batch-sizes",
        default=",".join(str(b) for b in model.BATCH_SIZES),
        help="comma-separated cohort batch widths for the *_batched family (empty to skip)",
    )
    args = ap.parse_args()

    batch_sizes = [int(b) for b in args.batch_sizes.split(",") if b.strip()]
    if any(b < 1 for b in batch_sizes):
        ap.error("--batch-sizes entries must be positive integers")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest: list = []
    for name in args.variants.split(","):
        variant = model.VARIANTS[name]
        print(
            f"[aot] {name}: n={variant.n_params} n'={variant.n_pad} m={variant.sketch_dim}",
            file=sys.stderr,
        )
        lower_variant(variant, args.out_dir, manifest)
        for b in batch_sizes:
            print(f"[aot] {name}: batched family at B={b}", file=sys.stderr)
            lower_variant_batched(variant, b, args.out_dir, manifest)
    write_if_changed(os.path.join(args.out_dir, "manifest.txt"), format_manifest(manifest))
    print(f"[aot] manifest: {len(manifest)} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
