"""L2: the pFed1BS client compute graph in JAX.

Defines the model variants from the paper's experimental setup (a 2-layer
MLP for the 784-dim datasets, a deeper MLP standing in for the VGG nets on
the 3072-dim datasets — see DESIGN.md §2 for the substitution note), the
smoothed personalized objective

    F~_k(w; v) = f_k(w) + lambda * g~(v, Phi w) + (mu/2) ||w||^2   (Eq. 6)

and the functions that ``aot.py`` lowers to HLO artifacts:

    client_step   one SGD step on F~_k   (Algorithm 1, line 16)
    sgd_step      one SGD step on f_k + (mu/2)||w||^2 (baselines; no FHT)
    sketch        z = sign(Phi w)        (Algorithm 1, line 18)
    eval_batch    (#correct, loss_sum) on a test batch
    grad_norm     ||grad F~_k||^2        (Theorem 1 diagnostics)

Models operate on a FLAT parameter vector w in R^n so that the sketching
operator, the rust coordinator, and the communication codecs all see one
contiguous buffer; (un)flattening happens inside the graph with static
slices, which XLA folds away.

Hyperparameters (eta, lambda, mu, gamma) are runtime f32 scalars — the
sensitivity sweeps of Appendix Table 1 reuse one compiled artifact.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import fht
from compile.kernels.ref import next_pow2

TRAIN_BATCH = 32
EVAL_BATCH = 256


@dataclasses.dataclass(frozen=True)
class ModelVariant:
    """A fixed architecture + sketch geometry, one set of artifacts each."""

    name: str
    input_dim: int
    hidden: Tuple[int, ...]
    classes: int
    sketch_ratio: float = 0.1  # m/n, paper fixes 0.1

    @property
    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = [self.input_dim, *self.hidden, self.classes]
        return [(dims[i], dims[i + 1]) for i in range(len(dims) - 1)]

    @property
    def n_params(self) -> int:
        return sum(i * o + o for i, o in self.layer_dims)

    @property
    def n_pad(self) -> int:
        return next_pow2(self.n_params)

    @property
    def sketch_dim(self) -> int:
        return int(self.sketch_ratio * self.n_params)


# The three variants used across the paper's five datasets (DESIGN.md §6).
# Hidden widths are sized so n' (next pow2 of n) stays one power of two
# smaller than the naive choice — the FHT butterflies are memory-bound, so
# this halves the regularizer's cost on this CPU testbed (DESIGN.md §6/§8):
#   mlp784:  n=101,652  -> n' = 2^17
#   mlp3072: n=453,682  -> n' = 2^19  (c100: 460,252 -> 2^19)
VARIANTS = {
    "mlp784": ModelVariant("mlp784", 784, (128,), 10),
    "mlp3072": ModelVariant("mlp3072", 3072, (144, 72), 10),
    "mlp3072c100": ModelVariant("mlp3072c100", 3072, (144, 72), 100),
}


def unflatten(variant: ModelVariant, w: jnp.ndarray):
    """Flat parameter vector -> [(W, b), ...] with static slices."""
    params = []
    off = 0
    for fan_in, fan_out in variant.layer_dims:
        size = fan_in * fan_out
        W = w[off : off + size].reshape(fan_in, fan_out)
        off += size
        b = w[off : off + fan_out]
        off += fan_out
        params.append((W, b))
    assert off == variant.n_params
    return params


def forward(variant: ModelVariant, w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """MLP forward pass: relu hidden layers, raw logits out."""
    params = unflatten(variant, w)
    h = x
    for i, (W, b) in enumerate(params):
        h = h @ W + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def task_loss(variant: ModelVariant, w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy over the batch (y: int32 labels)."""
    logits = forward(variant, w, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(nll)


def client_step(
    variant: ModelVariant,
    w: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    v: jnp.ndarray,
    dsign: jnp.ndarray,
    sidx: jnp.ndarray,
    eta: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
    gamma: jnp.ndarray,
):
    """One local SGD step on the smoothed objective (Algorithm 1, line 16):

        w <- w - eta * ( grad f_k(w; B) + lambda * Phi^T(tanh(gamma Phi w) - v)
                         + mu * w )

    The task gradient comes from autodiff; the regularizer gradient has the
    closed form of Eq. 7 and is computed by the fused Pallas kernel (one
    VMEM-resident forward+adjoint butterfly pass).
    Returns (w', task_loss).
    """
    loss, g_task = jax.value_and_grad(lambda ww: task_loss(variant, ww, x, y))(w)
    g_reg = fht.reg_grad_pallas(w, v, dsign, sidx, gamma)
    w_new = w - eta * (g_task + lam * g_reg + mu * w)
    return w_new, loss


def sgd_step(
    variant: ModelVariant,
    w: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    eta: jnp.ndarray,
    mu: jnp.ndarray,
):
    """Plain local SGD step (lambda = 0 path) used by every baseline.

    Kept as a separate artifact so baselines do not pay the two FHT
    butterflies of the regularizer; identical to ``client_step`` with
    lam = 0 (covered by a pytest equivalence check).
    """
    loss, g_task = jax.value_and_grad(lambda ww: task_loss(variant, ww, x, y))(w)
    w_new = w - eta * (g_task + mu * w)
    return w_new, loss


def client_step_w(variant: ModelVariant, w, x, y, v, dsign, sidx, eta, lam, mu, gamma):
    """client_step returning ONLY w' — lowered WITHOUT a tuple root so the
    rust runtime can feed the output device buffer straight back as the
    next step's input, keeping w device-resident across all R local steps
    (EXPERIMENTS.md §Perf: removes 2·n f32 host transfers per step)."""
    w_new, _ = client_step(variant, w, x, y, v, dsign, sidx, eta, lam, mu, gamma)
    return w_new


def sgd_step_w(variant: ModelVariant, w, x, y, eta, mu):
    """sgd_step returning only w' (single non-tuple output; see above)."""
    w_new, _ = sgd_step(variant, w, x, y, eta, mu)
    return w_new


def sketch(variant: ModelVariant, w: jnp.ndarray, dsign: jnp.ndarray, sidx: jnp.ndarray):
    """One-bit sketch z = sign(Phi w) in {-1,+1}^m (Algorithm 1, line 18)."""
    return (fht.sketch_sign_pallas(w, dsign, sidx),)


def eval_batch(variant: ModelVariant, w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """(#correct, summed NLL) over one eval batch; rust accumulates.

    Rows with label < 0 are padding (the rust loader zero-fills the final
    partial batch) and are masked out of both counts, so the accumulated
    statistics are exact regardless of batch alignment.
    """
    y = y.astype(jnp.int32)
    valid = (y >= 0).astype(jnp.float32)
    logits = forward(variant, w, x)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == y).astype(jnp.float32) * valid)
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe_y = jnp.maximum(y, 0)
    nll = -jnp.take_along_axis(logp, safe_y[:, None], axis=-1)[:, 0]
    return correct, jnp.sum(nll * valid)


def grad_norm(
    variant: ModelVariant,
    w: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    v: jnp.ndarray,
    dsign: jnp.ndarray,
    sidx: jnp.ndarray,
    lam: jnp.ndarray,
    mu: jnp.ndarray,
    gamma: jnp.ndarray,
):
    """||grad F~_k(w; v)||^2 — the quantity bounded by Theorem 1.

    Exposed as an artifact so the rust coordinator can log the
    stationarity measure per round (``fig3-4 --diagnostics``).
    """
    g_task = jax.grad(lambda ww: task_loss(variant, ww, x, y))(w)
    g_reg = fht.reg_grad_pallas(w, v, dsign, sidx, gamma)
    g = g_task + lam * g_reg + mu * w
    return (jnp.sum(g * g),)


# Cohort batch widths lowered by default (DESIGN.md §15). The rust runtime
# picks the largest width <= the configured device_batch and pads short
# tails, so this list only needs to cover the sweep/bench points.
BATCH_SIZES = (4, 8, 32, 64)


def client_step_batched(variant: ModelVariant, w, x, y, v, dsign, sidx, eta, lam, mu, gamma):
    """``client_step`` vmapped over a leading cohort axis B.

    Per-client state (w, data tile, personal sketch v) carries the batch
    axis; the shared SRHT operator (dsign, sidx) and the hyperparameter
    scalars are broadcast. vmap only stacks B independent per-client op
    DAGs — no cross-lane ops are introduced — which is the bit-identity
    argument of DESIGN.md §15. Returns (w' [B,n], loss [B]).
    """
    return jax.vmap(
        lambda wb, xb, yb, vb: client_step(variant, wb, xb, yb, vb, dsign, sidx, eta, lam, mu, gamma)
    )(w, x, y, v)


def client_step_batched_w(variant: ModelVariant, w, x, y, v, dsign, sidx, eta, lam, mu, gamma):
    """``client_step_batched`` returning ONLY the stacked w' — lowered
    tuple-free so the [B,n] weight buffer stays device-resident across
    all R local steps, exactly like the unbatched ``client_step_w``."""
    return jax.vmap(
        lambda wb, xb, yb, vb: client_step_w(variant, wb, xb, yb, vb, dsign, sidx, eta, lam, mu, gamma)
    )(w, x, y, v)


def sketch_batched(variant: ModelVariant, w, dsign, sidx):
    """One-bit sketches for a stacked cohort: sign(Phi w_k) per lane."""
    return (jax.vmap(lambda wb: sketch(variant, wb, dsign, sidx)[0])(w),)


def example_shapes(variant: ModelVariant):
    """ShapeDtypeStructs for lowering each artifact of this variant."""
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    n, npad, m, d = variant.n_params, variant.n_pad, variant.sketch_dim, variant.input_dim
    w = s((n,), f32)
    xb = s((TRAIN_BATCH, d), f32)
    yb = s((TRAIN_BATCH,), i32)
    xe = s((EVAL_BATCH, d), f32)
    ye = s((EVAL_BATCH,), i32)
    v = s((m,), f32)
    dsign = s((npad,), f32)
    sidx = s((m,), i32)
    scalar = s((), f32)
    return {
        "client_step": (w, xb, yb, v, dsign, sidx, scalar, scalar, scalar, scalar),
        "client_step_w": (w, xb, yb, v, dsign, sidx, scalar, scalar, scalar, scalar),
        "sgd_step": (w, xb, yb, scalar, scalar),
        "sgd_step_w": (w, xb, yb, scalar, scalar),
        "sketch": (w, dsign, sidx),
        "eval": (w, xe, ye),
        "grad_norm": (w, xb, yb, v, dsign, sidx, scalar, scalar, scalar),
    }


def batched_shapes(variant: ModelVariant, b: int):
    """ShapeDtypeStructs for the cohort-batched artifacts at width ``b``.

    Only the per-client arguments (w, data tile, v) gain the leading B
    axis; the shared operator and scalars keep the unbatched shapes, so
    the rust runtime reuses its existing dsign/sidx device uploads.
    """
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    n, npad, m, d = variant.n_params, variant.n_pad, variant.sketch_dim, variant.input_dim
    w = s((b, n), f32)
    xb = s((b, TRAIN_BATCH, d), f32)
    yb = s((b, TRAIN_BATCH), i32)
    v = s((b, m), f32)
    dsign = s((npad,), f32)
    sidx = s((m,), i32)
    scalar = s((), f32)
    return {
        "client_step_batched": (w, xb, yb, v, dsign, sidx, scalar, scalar, scalar, scalar),
        "client_step_batched_w": (w, xb, yb, v, dsign, sidx, scalar, scalar, scalar, scalar),
        "sketch_batched": (w, dsign, sidx),
    }


def artifact_fns(variant: ModelVariant):
    """name -> python callable, closed over the variant."""
    return {
        "client_step": lambda *a: client_step(variant, *a),
        "client_step_w": lambda *a: client_step_w(variant, *a),
        "sgd_step": lambda *a: sgd_step(variant, *a),
        "sgd_step_w": lambda *a: sgd_step_w(variant, *a),
        "sketch": lambda *a: sketch(variant, *a),
        "eval": lambda *a: eval_batch(variant, *a),
        "grad_norm": lambda *a: grad_norm(variant, *a),
    }


def batched_fns(variant: ModelVariant):
    """name -> python callable for the cohort-batched artifact family."""
    return {
        "client_step_batched": lambda *a: client_step_batched(variant, *a),
        "client_step_batched_w": lambda *a: client_step_batched_w(variant, *a),
        "sketch_batched": lambda *a: sketch_batched(variant, *a),
    }
