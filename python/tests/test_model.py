"""L2 correctness: client objective, gradients, and step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def tiny_variant(classes=4):
    return model.ModelVariant("tiny", 12, (8,), classes)


def rand_inputs(variant, rng, batch=None):
    b = batch or model.TRAIN_BATCH
    w = jnp.asarray(0.1 * rng.standard_normal(variant.n_params), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, variant.input_dim)), jnp.float32)
    y = jnp.asarray(rng.integers(0, variant.classes, b), jnp.int32)
    return w, x, y


def rand_operator(variant, rng):
    d = jnp.asarray(rng.choice([-1.0, 1.0], variant.n_pad), jnp.float32)
    s = jnp.asarray(rng.choice(variant.n_pad, variant.sketch_dim, replace=False), jnp.int32)
    v = jnp.asarray(rng.choice([-1.0, 1.0], variant.sketch_dim), jnp.float32)
    return d, s, v


# ----------------------------------------------------------------- plumbing


def test_variant_param_counts():
    assert model.VARIANTS["mlp784"].n_params == 784 * 128 + 128 + 128 * 10 + 10
    assert model.VARIANTS["mlp784"].n_pad == 1 << 17
    assert model.VARIANTS["mlp3072"].n_params == (
        3072 * 144 + 144 + 144 * 72 + 72 + 72 * 10 + 10
    )
    assert model.VARIANTS["mlp3072"].n_pad == 1 << 19
    assert model.VARIANTS["mlp3072c100"].classes == 100
    for v in model.VARIANTS.values():
        assert v.sketch_dim == int(0.1 * v.n_params)


def test_unflatten_round_trip():
    variant = tiny_variant()
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(variant.n_params), jnp.float32)
    params = model.unflatten(variant, w)
    flat = jnp.concatenate([jnp.concatenate([W.reshape(-1), b]) for W, b in params])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(w))


def test_forward_shapes():
    variant = tiny_variant(classes=5)
    rng = np.random.default_rng(1)
    w, x, _ = rand_inputs(variant, rng, batch=7)
    logits = model.forward(variant, w, x)
    assert logits.shape == (7, 5)


# ------------------------------------------------------------------- losses


def test_task_loss_matches_manual_softmax():
    variant = tiny_variant()
    rng = np.random.default_rng(2)
    w, x, y = rand_inputs(variant, rng, batch=16)
    logits = np.asarray(model.forward(variant, w, x))
    ex = np.exp(logits - logits.max(1, keepdims=True))
    p = ex / ex.sum(1, keepdims=True)
    want = -np.log(p[np.arange(16), np.asarray(y)]).mean()
    got = float(model.task_loss(variant, w, x, y))
    assert abs(got - want) < 1e-5


def test_uniform_logits_loss_is_log_c():
    variant = tiny_variant(classes=8)
    w = jnp.zeros((variant.n_params,), jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, variant.input_dim)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)
    assert abs(float(model.task_loss(variant, w, x, y)) - np.log(8.0)) < 1e-5


# -------------------------------------------------------------------- steps


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_client_step_matches_manual_update(seed):
    """w' must equal w - eta*(g_task + lam*Phi^T(tanh(gamma Phi w)-v) + mu*w)."""
    variant = tiny_variant()
    rng = np.random.default_rng(seed)
    w, x, y = rand_inputs(variant, rng, batch=8)
    d, s, v = rand_operator(variant, rng)
    eta, lam, mu, gamma = 0.05, 3e-3, 1e-4, 100.0

    w2, loss = model.client_step(
        variant, w, x, y, v, d, s,
        jnp.float32(eta), jnp.float32(lam), jnp.float32(mu), jnp.float32(gamma),
    )
    g_task = jax.grad(lambda ww: model.task_loss(variant, ww, x, y))(w)
    g_reg = ref.reg_grad_ref(w, v, d, s, jnp.float32(gamma))
    want = w - eta * (g_task + lam * g_reg + mu * w)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(want), rtol=1e-4, atol=1e-5)
    assert abs(float(loss) - float(model.task_loss(variant, w, x, y))) < 1e-5


def test_client_step_with_lam0_equals_sgd_step():
    variant = tiny_variant()
    rng = np.random.default_rng(10)
    w, x, y = rand_inputs(variant, rng, batch=8)
    d, s, v = rand_operator(variant, rng)
    a, la = model.client_step(
        variant, w, x, y, v, d, s,
        jnp.float32(0.1), jnp.float32(0.0), jnp.float32(1e-5), jnp.float32(1e4),
    )
    b, lb = model.sgd_step(variant, w, x, y, jnp.float32(0.1), jnp.float32(1e-5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    assert float(la) == pytest.approx(float(lb), abs=1e-6)


def test_client_step_descends_objective():
    """A small-eta step must not increase the smoothed objective F~_k."""
    variant = tiny_variant()
    rng = np.random.default_rng(12)
    w, x, y = rand_inputs(variant, rng, batch=32)
    d, s, v = rand_operator(variant, rng)
    eta, lam, mu, gamma = 0.01, 1e-3, 1e-5, 10.0

    def objective(ww):
        return (
            float(model.task_loss(variant, ww, x, y))
            + lam * float(ref.reg_value_ref(ww, v, d, s, jnp.float32(gamma)))
            + 0.5 * mu * float(jnp.sum(ww * ww))
        )

    w2, _ = model.client_step(
        variant, w, x, y, v, d, s,
        jnp.float32(eta), jnp.float32(lam), jnp.float32(mu), jnp.float32(gamma),
    )
    assert objective(w2) <= objective(w) + 1e-6


def test_sign_regularizer_pulls_sketch_toward_consensus():
    """Repeated reg-only steps must reduce sign disagreement with v."""
    variant = tiny_variant()
    rng = np.random.default_rng(13)
    w = jnp.asarray(0.1 * rng.standard_normal(variant.n_params), jnp.float32)
    d, s, _ = rand_operator(variant, rng)
    v = jnp.asarray(rng.choice([-1.0, 1.0], variant.sketch_dim), jnp.float32)

    def disagreement(ww):
        z = ref.sketch_sign_ref(ww, d, s)
        return float(jnp.sum(z != v))

    before = disagreement(w)
    gamma = jnp.float32(50.0)
    for _ in range(200):
        g = ref.reg_grad_ref(w, v, d, s, gamma)
        w = w - 0.01 * g
    after = disagreement(w)
    assert after <= before
    assert after == 0  # reg-only dynamics can fully align signs


# --------------------------------------------------------------------- eval


def test_eval_batch_counts():
    variant = tiny_variant()
    rng = np.random.default_rng(14)
    w, x, y = rand_inputs(variant, rng, batch=64)
    correct, loss_sum = model.eval_batch(variant, w, x, y)
    logits = np.asarray(model.forward(variant, w, x))
    want = (logits.argmax(1) == np.asarray(y)).sum()
    assert float(correct) == want
    assert float(loss_sum) == pytest.approx(
        float(model.task_loss(variant, w, x, y)) * 64, rel=1e-5
    )


def test_grad_norm_matches_manual():
    variant = tiny_variant()
    rng = np.random.default_rng(15)
    w, x, y = rand_inputs(variant, rng, batch=8)
    d, s, v = rand_operator(variant, rng)
    lam, mu, gamma = 2e-3, 1e-4, 50.0
    (gn,) = model.grad_norm(
        variant, w, x, y, v, d, s,
        jnp.float32(lam), jnp.float32(mu), jnp.float32(gamma),
    )
    g_task = jax.grad(lambda ww: model.task_loss(variant, ww, x, y))(w)
    g = g_task + lam * ref.reg_grad_ref(w, v, d, s, jnp.float32(gamma)) + mu * w
    assert float(gn) == pytest.approx(float(jnp.sum(g * g)), rel=1e-4)


def test_eval_batch_masks_padding():
    """Rows with label -1 contribute neither correct counts nor loss."""
    variant = tiny_variant()
    rng = np.random.default_rng(16)
    w, x, y = rand_inputs(variant, rng, batch=64)
    c_full, l_full = model.eval_batch(variant, w, x, y)
    y_masked = np.asarray(y).copy()
    y_masked[32:] = -1
    c_half, l_half = model.eval_batch(variant, w, x, jnp.asarray(y_masked))
    c_head, l_head = model.eval_batch(
        variant, w, x[:32], jnp.asarray(y_masked[:32])
    )
    assert float(c_half) == pytest.approx(float(c_head))
    assert float(l_half) == pytest.approx(float(l_head), rel=1e-5)
    assert float(c_half) <= float(c_full)
