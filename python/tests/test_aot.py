"""AOT pipeline: artifacts exist, manifest is consistent, HLO is loadable.

The cross-language numerical check (rust PJRT executes the artifact and
matches the jax value) lives in rust/tests/; here we verify the python
side of the contract: every manifest row points at a real file whose
content hash matches, the HLO parameter/result shapes agree with
``model.example_shapes``, and lowering is deterministic.
"""

import hashlib
import os
import re

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest_rows():
    path = os.path.join(ART, "manifest.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rows.append(dict(kv.split("=", 1) for kv in line.split(" ")))
    return rows


BATCHED_KINDS = {"client_step_batched", "client_step_batched_w", "sketch_batched"}


def test_manifest_covers_all_variants_and_fns():
    rows = manifest_rows()
    unbatched = [r for r in rows if "batch" not in r]
    got = {(r["artifact"], r["variant"]) for r in unbatched}
    want = {
        (fn, v)
        for v in model.VARIANTS
        for fn in (
            "client_step",
            "client_step_w",
            "sgd_step",
            "sgd_step_w",
            "sketch",
            "eval",
            "grad_norm",
        )
    }
    assert got == want


def test_batched_manifest_rows_form_complete_families():
    """Every batched row carries batch=B >= 1, and for each (variant, B)
    all three batched kinds are present — the rust loader only advertises
    complete families (manifest.rs batch_sizes)."""
    batched = [r for r in manifest_rows() if "batch" in r]
    if not batched:
        pytest.skip("no batched artifacts in manifest")
    assert {r["artifact"] for r in batched} <= BATCHED_KINDS
    families = {}
    for r in batched:
        b = int(r["batch"])
        assert b >= 1, r
        assert r["variant"] in model.VARIANTS
        families.setdefault((r["variant"], b), set()).add(r["artifact"])
    for (variant, b), arts in families.items():
        assert arts == BATCHED_KINDS, f"incomplete family {variant} batch={b}: {arts}"
    # the default lowering emits every width in model.BATCH_SIZES
    widths = {b for (_, b) in families}
    assert widths <= set(model.BATCH_SIZES) | {1}


def test_manifest_files_exist_and_hashes_match():
    for r in manifest_rows():
        path = os.path.join(ART, r["file"])
        assert os.path.exists(path), r["file"]
        with open(path) as f:
            digest = hashlib.sha256(f.read().encode()).hexdigest()[:16]
        assert digest == r["sha256"], f"stale artifact {r['file']}"


def test_manifest_geometry_matches_variants():
    for r in manifest_rows():
        v = model.VARIANTS[r["variant"]]
        assert int(r["n"]) == v.n_params
        assert int(r["npad"]) == v.n_pad
        assert int(r["m"]) == v.sketch_dim
        assert int(r["input_dim"]) == v.input_dim
        assert int(r["classes"]) == v.classes
        assert int(r["train_batch"]) == model.TRAIN_BATCH
        assert int(r["eval_batch"]) == model.EVAL_BATCH


def test_hlo_entry_has_expected_parameter_count():
    """client_step takes 10 parameters; check the HLO ENTRY signature."""
    rows = [r for r in manifest_rows() if r["artifact"] == "client_step"]
    for r in rows:
        with open(os.path.join(ART, r["file"])) as f:
            text = f.read()
        entry = re.search(r"ENTRY .*?\{(.*?)ROOT", text, re.S)
        assert entry is not None
        params = re.findall(r"parameter\((\d+)\)", entry.group(1))
        assert len(params) == 10, r["file"]
        n = int(r["n"])
        assert f"f32[{n}]" in text  # w in and w' out


def test_lowering_is_deterministic():
    v = model.ModelVariant("det", 16, (8,), 3)
    import jax

    shapes = model.example_shapes(v)["sgd_step"]
    fn = model.artifact_fns(v)["sgd_step"]
    a = aot.to_hlo_text(jax.jit(fn).lower(*shapes))
    b = aot.to_hlo_text(jax.jit(fn).lower(*shapes))
    assert a == b


def test_hlo_text_parseable_header():
    for r in manifest_rows()[:3]:
        with open(os.path.join(ART, r["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), r["file"]


def test_step_w_artifacts_have_non_tuple_root():
    """The *_w artifacts must be lowered WITHOUT a tuple root so the rust
    runtime can chain their output buffer into the next step (§Perf)."""
    for r in manifest_rows():
        with open(os.path.join(ART, r["file"])) as f:
            text = f.read()
        # the root of the ENTRY computation is the last ROOT instruction
        roots = re.findall(r"ROOT \S+ = (\S+)", text)
        assert roots, r["file"]
        ret = roots[-1]
        if r["artifact"].endswith("_w"):
            assert not ret.startswith("("), f"{r['file']} returns a tuple: {ret}"
        else:
            assert ret.startswith("("), f"{r['file']} should return a tuple: {ret}"


def test_step_w_matches_client_step_w_component():
    """client_step_w == first output of client_step, numerically."""
    import jax.numpy as jnp
    import numpy as np

    v = model.ModelVariant("tiny_w", 12, (8,), 4)
    rng = np.random.default_rng(0)
    w = jnp.asarray(0.1 * rng.standard_normal(v.n_params), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
    d = jnp.asarray(rng.choice([-1.0, 1.0], v.n_pad), jnp.float32)
    s = jnp.asarray(rng.choice(v.n_pad, v.sketch_dim, replace=False), jnp.int32)
    vv = jnp.asarray(rng.choice([-1.0, 1.0], v.sketch_dim), jnp.float32)
    args = (w, x, y, vv, d, s, jnp.float32(0.05), jnp.float32(1e-3),
            jnp.float32(1e-5), jnp.float32(100.0))
    w_a, _ = model.client_step(v, *args)
    w_b = model.client_step_w(v, *args)
    np.testing.assert_allclose(np.asarray(w_a), np.asarray(w_b), rtol=0, atol=0)


def _entry_param_shapes(text):
    """(dtype, dims) per ENTRY parameter, in parameter-index order."""
    entry = re.search(r"ENTRY .*?\{(.*?)ROOT", text, re.S)
    assert entry is not None
    params = {}
    for m in re.finditer(
        r"= (\w+)\[([\d,]*)\][^=\n]*parameter\((\d+)\)", entry.group(1)
    ):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        params[int(m.group(3))] = (m.group(1), dims)
    return [params[i] for i in sorted(params)]


def test_batched_b1_lowering_matches_unbatched_shape_for_shape():
    """vmap at B=1 must add exactly a leading 1-axis to the per-client
    params (w, x, y, v) and leave the shared params (dsign, sidx,
    scalars) untouched — the shape-level half of the bit-identity
    contract (the numeric half runs in rust/tests/integration_batched.rs)."""
    import jax

    v = model.ModelVariant("detb1", 16, (8,), 3)
    ub = aot.to_hlo_text(
        jax.jit(model.artifact_fns(v)["client_step"]).lower(
            *model.example_shapes(v)["client_step"]
        )
    )
    bt = aot.to_hlo_text(
        jax.jit(model.batched_fns(v)["client_step_batched"]).lower(
            *model.batched_shapes(v, 1)["client_step_batched"]
        )
    )
    ub_params = _entry_param_shapes(ub)
    bt_params = _entry_param_shapes(bt)
    assert len(ub_params) == len(bt_params) == 10
    for i, (u, b) in enumerate(zip(ub_params, bt_params)):
        assert u[0] == b[0], f"param {i} dtype"
        if i < 4:  # w, x, y, v gain the cohort axis
            assert b[1] == (1,) + u[1], f"param {i}: {b[1]} vs {u[1]}"
        else:  # dsign, sidx, eta, lam, mu, gamma are shared
            assert b[1] == u[1], f"param {i}: {b[1]} vs {u[1]}"
