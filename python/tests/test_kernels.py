"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the sketching hot path. Hypothesis
sweeps sizes/dtypes/seeds; every kernel must agree with the oracle, and
the oracle itself is validated against dense linear algebra.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fht, ref


def make_operator(rng, n, npow, m):
    d = jnp.asarray(rng.choice([-1.0, 1.0], npow), jnp.float32)
    s = jnp.asarray(rng.choice(npow, m, replace=False), jnp.int32)
    return d, s


def dense_phi(d, s, n):
    """Materialize Phi = sqrt(n'/m) S H D P_pad as a dense matrix (tests)."""
    npow, m = d.shape[0], s.shape[0]
    H = ref.hadamard_dense(npow)
    P = (H * np.asarray(d)[None, :])[np.asarray(s), :n]
    return P * math.sqrt(npow / m)


# ---------------------------------------------------------------------- fwht


@settings(max_examples=20, deadline=None)
@given(log2n=st.integers(0, 10), seed=st.integers(0, 2**31 - 1))
def test_fwht_ref_matches_dense(log2n, seed):
    n = 1 << log2n
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    want = ref.hadamard_dense(n) @ x
    got = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(log2n=st.integers(0, 12), seed=st.integers(0, 2**31 - 1))
def test_fwht_pallas_matches_ref(log2n, seed):
    n = 1 << log2n
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(fht.fwht_pallas(x)), np.asarray(ref.fwht_ref(x)), rtol=1e-5, atol=1e-5
    )


def test_fwht_is_involution():
    """Normalized H is its own inverse: H(Hx) = x."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    back = ref.fwht_ref(ref.fwht_ref(x))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-4, atol=1e-4)


def test_fwht_preserves_l2_norm():
    """Orthonormality: ||Hx|| = ||x||."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    assert abs(float(jnp.linalg.norm(ref.fwht_ref(x))) - float(jnp.linalg.norm(x))) < 1e-2


def test_fwht_rejects_non_pow2():
    with pytest.raises(AssertionError):
        ref.fwht_ref(jnp.zeros((12,), jnp.float32))


# ---------------------------------------------------------------------- srht


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 500),
    ratio=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_srht_forward_matches_dense(n, ratio, seed):
    npow = ref.next_pow2(n)
    m = max(1, int(ratio * n))
    rng = np.random.default_rng(seed)
    d, s = make_operator(rng, n, npow, m)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    want = dense_phi(d, s, n) @ np.asarray(w)
    got = np.asarray(ref.srht_forward_ref(w, d, s))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    got_pl = np.asarray(fht.srht_forward_pallas(w, d, s))
    np.testing.assert_allclose(got_pl, got, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 500),
    ratio=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_srht_adjoint_identity(n, ratio, seed):
    """<Phi x, y> == <x, Phi^T y> for random x, y — the defining property."""
    npow = ref.next_pow2(n)
    m = max(1, int(ratio * n))
    rng = np.random.default_rng(seed)
    d, s = make_operator(rng, n, npow, m)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    lhs = float(jnp.dot(ref.srht_forward_ref(x, d, s), y))
    rhs = float(jnp.dot(x, ref.srht_adjoint_ref(y, d, s, n)))
    scale = max(1.0, abs(lhs))
    assert abs(lhs - rhs) / scale < 1e-3


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 300), seed=st.integers(0, 2**31 - 1))
def test_srht_adjoint_pallas_matches_ref(n, seed):
    npow = ref.next_pow2(n)
    m = max(1, n // 10)
    rng = np.random.default_rng(seed)
    d, s = make_operator(rng, n, npow, m)
    v = jnp.asarray(rng.choice([-1.0, 1.0], m).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(fht.srht_adjoint_pallas(v, d, s, n)),
        np.asarray(ref.srht_adjoint_ref(v, d, s, n)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_spectral_norm_lemma2():
    """Lemma 2: ||Phi|| = sqrt(n'/m) exactly (via dense SVD on small op)."""
    rng = np.random.default_rng(3)
    n, npow, m = 48, 64, 16
    d, s = make_operator(rng, n, npow, m)
    P = dense_phi(d, s, npow)[:, :]  # full n' columns: padded operator
    sv = np.linalg.svd(P, compute_uv=False)
    np.testing.assert_allclose(sv.max(), math.sqrt(npow / m), rtol=1e-5)


def test_srht_linearity():
    rng = np.random.default_rng(11)
    n, npow, m = 100, 128, 10
    d, s = make_operator(rng, n, npow, m)
    a = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    lhs = ref.srht_forward_ref(2.0 * a + 3.0 * b, d, s)
    rhs = 2.0 * ref.srht_forward_ref(a, d, s) + 3.0 * ref.srht_forward_ref(b, d, s)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- reg grad


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(8, 300),
    gamma=st.floats(0.5, 1e4),
    seed=st.integers(0, 2**31 - 1),
)
def test_reg_grad_pallas_matches_ref(n, gamma, seed):
    npow = ref.next_pow2(n)
    m = max(1, n // 10)
    rng = np.random.default_rng(seed)
    d, s = make_operator(rng, n, npow, m)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    v = jnp.asarray(rng.choice([-1.0, 1.0], m).astype(np.float32))
    got = np.asarray(fht.reg_grad_pallas(w, v, d, s, jnp.array([gamma], jnp.float32)))
    want = np.asarray(ref.reg_grad_ref(w, v, d, s, jnp.float32(gamma)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_reg_grad_matches_autodiff_of_reg_value():
    """Eq. 7 is the true gradient of Eq. 5: check against jax.grad."""
    import jax

    rng = np.random.default_rng(5)
    n, npow, m = 120, 128, 12
    d, s = make_operator(rng, n, npow, m)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    v = jnp.asarray(rng.choice([-1.0, 1.0], m).astype(np.float32))
    gamma = jnp.float32(3.0)
    auto = jax.grad(lambda ww: ref.reg_value_ref(ww, v, d, s, gamma))(w)
    closed = ref.reg_grad_ref(w, v, d, s, gamma)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(closed), rtol=1e-3, atol=1e-4)


def test_reg_grad_zero_when_aligned():
    """If v == sign(Phi w) and gamma is large, tanh(gamma z) ~ v so the
    residual (and hence the pull) is near zero wherever |Phi w| >> 1/gamma."""
    rng = np.random.default_rng(6)
    n, npow, m = 100, 128, 10
    d, s = make_operator(rng, n, npow, m)
    w = jnp.asarray(10.0 * rng.standard_normal(n).astype(np.float32))
    v = ref.sketch_sign_ref(w, d, s)
    g = ref.reg_grad_ref(w, v, d, s, jnp.float32(1e4))
    assert float(jnp.max(jnp.abs(g))) < 1e-3


def test_sketch_sign_values():
    rng = np.random.default_rng(9)
    n, npow, m = 64, 64, 8
    d, s = make_operator(rng, n, npow, m)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    z = np.asarray(ref.sketch_sign_ref(w, d, s))
    assert set(np.unique(z)).issubset({-1.0, 1.0})
    zp = np.asarray(fht.sketch_sign_pallas(w, d, s))
    np.testing.assert_array_equal(z, zp)


# --------------------------------------------------------- server (Lemma 1)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 6),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_majority_vote_is_optimal_aggregation(k, m, seed):
    """Lemma 1: v* = sign(sum p_k z_k) minimizes sum p_k g(v, z_k) —
    verified by brute force over all 2^m candidate v."""
    rng = np.random.default_rng(seed)
    z = rng.choice([-1.0, 1.0], (k, m))
    p = rng.random(k) + 0.1
    p /= p.sum()
    agg = (p[:, None] * z).sum(0)
    vstar = np.where(agg >= 0, 1.0, -1.0)

    def obj(v):
        # g(v, z) = || [v ⊙ z]_- ||_1  (Eq. 2)
        return sum(pi * np.minimum(vi_zi, 0.0).__abs__().sum()
                   for pi, vi_zi in ((p[i], v * z[i]) for i in range(k)))

    best = min(
        obj(np.array([(1.0 if (c >> b) & 1 else -1.0) for b in range(m)]))
        for c in range(1 << m)
    )
    assert obj(vstar) <= best + 1e-9
