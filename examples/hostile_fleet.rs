//! Hostile-fleet scenario sweep — Byzantine sign-flipping clients
//! versus the three sign-tally aggregators, driven through the round
//! engine with attack injection at the uplink boundary.
//!
//! Question a practitioner actually asks: *how large an adversarial
//! fraction can the one-bit consensus absorb before the personalized
//! models feel it, and how much does a robust tally buy back?* Each
//! cell reports the final personalized accuracy, the total consensus
//! sign churn over the run (a corrupted tally keeps flipping bits the
//! honest majority had settled), and the adversarial uplinks marked.
//!
//! ```bash
//! cargo run --release --example hostile_fleet [ROUNDS]
//! ```

use anyhow::Result;
use pfed1bs::algorithms;
use pfed1bs::config::{Attack, RunConfig};
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(15);

    // attack fraction × aggregator grid: the same sign-flip fleet seen
    // by the plain majority vote, the coordinate-wise trimmed vote
    // (trim 30% per tail), and a 5-group median-of-means tally
    let fractions = [0.0, 0.15, 0.3, 0.45];
    let aggregators: [(&str, f64, usize); 3] =
        [("vote", 0.0, 1), ("trimmed:0.3", 0.3, 1), ("mom:5", 0.0, 5)];

    println!("hostile fleet: pfed1bs, signflip adversaries, {rounds} rounds");
    println!(
        "{:>9} {:>12} {:>12} {:>12} {:>11}",
        "attack F", "aggregator", "final acc %", "flips", "adversaries"
    );

    let lab = Lab::new("artifacts")?;
    for &frac in &fractions {
        for &(label, trim_frac, mom_groups) in &aggregators {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.rounds = rounds;
            cfg.trim_frac = trim_frac;
            cfg.mom_groups = mom_groups;
            if frac > 0.0 {
                cfg.attack = Attack::SignFlip { frac };
            }
            cfg.validate()?;

            let model = lab.model_for(&cfg)?;
            let mut alg = algorithms::build("pfed1bs")?;
            let mut coord = Coordinator::new(cfg, &model);
            let result = coord.run(alg.as_mut())?;

            let recs = &result.history.records;
            let flips: usize = recs.iter().filter_map(|r| r.consensus_flips).sum();
            let marked: usize = recs.iter().map(|r| r.adversaries).sum();
            println!(
                "{:>9.2} {:>12} {:>12.2} {:>12} {:>11}",
                frac,
                label,
                100.0 * result.final_accuracy,
                flips,
                marked,
            );
        }
    }
    println!(
        "\nreading: the plain vote rides on its honest margin — small fleets of \
         flippers only thin it, but past ~1/3 the consensus churns and accuracy \
         follows. The trimmed vote discards both tails of every coordinate's \
         per-client quanta before summing, and median-of-means outvotes corrupted \
         groups; both hold the floor at fractions where the raw vote has already \
         given the adversary the broadcast."
    );
    Ok(())
}
