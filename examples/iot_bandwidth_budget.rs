//! IoT bandwidth-budget scenario — the paper's motivating deployment
//! ("massive IoT … extremely constrained bandwidth" + unreliable links).
//!
//! Question a practitioner actually asks: *given a fixed total
//! communication budget in MB, which algorithm reaches the best
//! personalized accuracy?* Every algorithm trains until it exhausts the
//! budget (not a fixed round count), so heavyweight methods get few
//! rounds and one-bit methods get many. Optionally adds per-link
//! bit-flip noise to model lossy radios (each client's channel corrupts
//! independently).
//!
//! ```bash
//! cargo run --release --example iot_bandwidth_budget [BUDGET_MB] [FLIP_PROB]
//! ```

use anyhow::Result;
use pfed1bs::algorithms;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::{evaluate, Coordinator};
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();
    let budget_mb: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8.0);
    let flip: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(0.0);

    println!("IoT scenario: budget {budget_mb} MB total, uplink bit-flip p={flip}");
    println!("{:<10} {:>7} {:>10} {:>12}", "algorithm", "rounds", "MB used", "final acc %");

    let lab = Lab::new("artifacts")?;
    for alg_name in ["pfed1bs", "obda", "obcsaa", "zsignfed", "eden", "fedbat", "fedavg"] {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.algorithm = alg_name.to_string();
        cfg.rounds = 10_000; // budget-terminated below
        let model = lab.model_for(&cfg)?;
        let mut alg = algorithms::build(alg_name)?;
        let mut coord = Coordinator::new(cfg, &model);
        coord.net.bit_flip_prob = flip;

        // budget-terminated manual round loop over the phased protocol
        let budget_bytes = (budget_mb * 1024.0 * 1024.0) as u64;
        let mut rounds = 0usize;
        coord.init_algorithm(alg.as_mut())?;
        let mut rng = pfed1bs::util::rng::Rng::new(coord.cfg.seed ^ 0xB0D6E7);
        while coord.net.ledger.total_bytes() < budget_bytes && rounds < 150 {
            let selected = rng.sample_without_replacement(coord.cfg.clients, coord.cfg.participating);
            let raw: Vec<f32> = selected.iter().map(|&k| coord.data.weights[k]).collect();
            let total: f32 = raw.iter().sum();
            let weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();
            coord.run_round(alg.as_mut(), rounds, &selected, &weights)?;
            coord.net.end_round();
            rounds += 1;
        }
        let ev = evaluate(coord.model, &coord.data, alg.as_ref())?;
        println!(
            "{:<10} {:>7} {:>10.2} {:>12.2}",
            alg_name,
            rounds,
            coord.net.ledger.total_bytes() as f64 / (1024.0 * 1024.0),
            100.0 * ev.accuracy
        );
    }
    println!("\n(one-bit sketching buys pFed1BS two orders of magnitude more rounds per MB; round cap 150)");
    Ok(())
}
