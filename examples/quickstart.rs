//! Quickstart: train pFed1BS on the MNIST-like workload for a handful of
//! rounds and print the accuracy / communication trade-off.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use pfed1bs::config::RunConfig;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();

    // 1. paper-aligned preset (20 clients, 2-class label shards, m/n=0.1,
    //    λ=5e-4, μ=1e-5, γ=1e4) with a short-horizon override
    let mut cfg = RunConfig::preset(DatasetName::Mnist);
    cfg.rounds = 10;
    cfg.eval_every = 2;

    // 2. the lab loads artifacts/ and compiles the HLO once
    let lab = Lab::new(&cfg.artifacts_dir)?;

    // 3. run — the coordinator samples clients, runs local steps through
    //    the AOT client_step executable, exchanges one-bit sketches, and
    //    majority-votes the consensus (Algorithm 1)
    println!("running: {}", cfg.summary());
    let result = lab.run(cfg)?;

    println!("\nquickstart result");
    println!("  personalized top-1 accuracy: {:.2}%", 100.0 * result.final_accuracy);
    println!("  mean communication per round: {:.4} MB", result.mean_round_mb);
    // FedAvg reference: n f32 × (S up + S down) per round, n = 101,770
    let fedavg_mb = 101_770.0 * 4.0 * 40.0 / (1024.0 * 1024.0);
    println!(
        "  (FedAvg at this scale moves ~{:.1} MB per round — pFed1BS uses {:.2}% of that)",
        fedavg_mb,
        100.0 * result.mean_round_mb / fedavg_mb
    );
    for r in result.history.records.iter().filter(|r| r.test_acc.is_some()) {
        println!(
            "  round {:>3}: train_loss={:.4} acc={:.4}",
            r.round,
            r.train_loss,
            r.test_acc.unwrap()
        );
    }
    Ok(())
}
