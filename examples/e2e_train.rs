//! End-to-end validation driver (EXPERIMENTS.md §E2E): full federated
//! training on the MNIST-like workload — 20 clients, non-i.i.d. label
//! shards, a few hundred communication rounds — exercising every layer:
//! L1 Pallas SRHT kernels (inside the AOT HLO), L2 client_step/eval
//! graphs, L3 coordinator + one-bit transport + majority-vote server.
//!
//! Logs the loss/accuracy curve to results/e2e_train.csv and asserts the
//! run actually learned (acc > 90% on the personalized metric).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [ROUNDS]
//! ```

use anyhow::Result;
use pfed1bs::config::RunConfig;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let mut cfg = RunConfig::preset(DatasetName::Mnist);
    cfg.rounds = rounds;
    cfg.eval_every = 5;
    println!("e2e: {}", cfg.summary());

    let lab = Lab::new(&cfg.artifacts_dir)?;
    let t0 = std::time::Instant::now();
    let result = lab.run_with_diagnostics(cfg.clone(), true)?;
    let wall = t0.elapsed().as_secs_f64();

    result
        .history
        .write_csv("results/e2e_train.csv", &cfg.summary())?;

    let total_steps = cfg.rounds * cfg.participating * cfg.local_steps;
    println!("\n=== e2e summary ===");
    println!("rounds:               {}", cfg.rounds);
    println!("local SGD steps run:  {total_steps}");
    println!("wall clock:           {wall:.1} s  ({:.1} steps/s)", total_steps as f64 / wall);
    println!("final accuracy:       {:.2}%", 100.0 * result.final_accuracy);
    println!("final test loss:      {:.4}", result.final_loss);
    println!("mean round comm:      {:.4} MB", result.mean_round_mb);
    println!("total comm:           {:.2} MB", result.history.total_mb());
    if let Some(r) = result.history.rounds_to_accuracy(0.9) {
        println!("rounds to 90% acc:    {r}");
    }
    println!("curve: results/e2e_train.csv");

    // the whole point of an e2e driver: fail loudly if the system did not
    // actually learn
    anyhow::ensure!(
        result.final_accuracy > 0.90,
        "e2e run failed to learn: accuracy {:.4} <= 0.90",
        result.final_accuracy
    );
    println!("e2e OK");
    Ok(())
}
