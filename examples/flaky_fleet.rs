//! Flaky-fleet scenario sweep — the unreliable, heterogeneous edge
//! deployments (IoT/V2X) the paper motivates, driven through the
//! event-driven round engine: a lognormal-latency fleet with
//! over-selection, swept over dropout probability × per-round deadline.
//!
//! Question a practitioner actually asks: *how much accuracy does
//! pFed1BS lose when a fraction of the fleet vanishes every round and
//! the server refuses to wait for stragglers?* Each cell reports the
//! mean delivered fraction (accepted uplinks / target S), the total
//! stragglers cut, and the final personalized accuracy.
//!
//! ```bash
//! cargo run --release --example flaky_fleet [ROUNDS]
//! ```

use anyhow::Result;
use pfed1bs::algorithms;
use pfed1bs::comm::LatencyModel;
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(15);

    // a heterogeneous fleet: median 10 ms uplinks with a heavy lognormal
    // tail, the server over-selecting 25% beyond its target of S = 12
    let latency = LatencyModel::LogNormal { median_ms: 10.0, sigma: 0.75 };
    let dropouts = [0.0, 0.15, 0.35];
    let deadlines = [0.0, 40.0, 15.0]; // ms; 0 = wait for everyone

    println!(
        "flaky fleet: pfed1bs, S=12 (+3 over-selected) of K=20, {rounds} rounds, \
         latency {}",
        latency.summary()
    );
    println!(
        "{:>8} {:>9} {:>11} {:>9} {:>12}",
        "dropout", "deadline", "delivered%", "cut", "final acc %"
    );

    let lab = Lab::new("artifacts")?;
    for &dropout in &dropouts {
        for &deadline in &deadlines {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.rounds = rounds;
            cfg.participating = 12;
            cfg.over_select = 3;
            cfg.dropout_prob = dropout;
            cfg.deadline_ms = deadline;
            cfg.latency = latency;
            cfg.validate()?;

            let model = lab.model_for(&cfg)?;
            let mut alg = algorithms::build("pfed1bs")?;
            let target = cfg.participating as f64;
            let mut coord = Coordinator::new(cfg, &model);
            let result = coord.run(alg.as_mut())?;

            let recs = &result.history.records;
            let delivered_frac = recs
                .iter()
                .map(|r| r.delivered as f64 / target)
                .sum::<f64>()
                / recs.len().max(1) as f64;
            let cut: usize = recs.iter().map(|r| r.stragglers_cut).sum();
            println!(
                "{:>8.2} {:>9} {:>11.1} {:>9} {:>12.2}",
                dropout,
                if deadline == 0.0 { "none".to_string() } else { format!("{deadline}ms") },
                100.0 * delivered_frac,
                cut,
                100.0 * result.final_accuracy,
            );
        }
    }
    println!(
        "\nreading: a tight deadline trades delivered fraction for wall-clock; \
         the majority vote degrades gracefully as long as the delivered set \
         stays a representative sample of the fleet."
    );
    Ok(())
}
