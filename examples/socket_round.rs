//! One multi-process pFed1BS round shape over a real TCP socket, all in
//! one process (DESIGN.md §12): a root server thread (`pfed1bs serve`'s
//! internals), a mock client fleet thread (`pfed1bs client-fleet`'s),
//! and the bit-identity check between the socket run's consensus and the
//! in-process reference replay.
//!
//! ```bash
//! cargo run --release --example socket_round [CLIENTS] [ROUNDS]
//! ```
//!
//! Needs no PJRT artifacts: the fleet is the deterministic mock protocol
//! (each sketch keyed on the *received* consensus, so the final words
//! checksum every byte of every round). The same two halves run as
//! separate OS processes via `pfed1bs serve` / `pfed1bs client-fleet` —
//! see the README's multi-process quickstart.

use anyhow::Result;
use pfed1bs::comm::transport::stream::Listener;
use pfed1bs::config::{Endpoint, ServeConfig, ServeRole};
use pfed1bs::serve::{reference_consensus, run_fleet, run_root_on};

fn main() -> Result<()> {
    let clients: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let rounds: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(3);

    let mut root_cfg = ServeConfig::new(ServeRole::Root);
    root_cfg.clients = clients;
    root_cfg.participating = (clients / 4).max(1);
    root_cfg.rounds = rounds;

    // bind an ephemeral port, then hand the resolved address to the fleet
    let listener = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0")?)?;
    let ep = listener.local_endpoint()?;
    println!(
        "root listening on {} — {} clients, {} of them per round, {} rounds",
        ep.summary(),
        clients,
        root_cfg.participating,
        rounds
    );

    let mut fleet_cfg = ServeConfig::new(ServeRole::Fleet);
    fleet_cfg.clients = clients;
    fleet_cfg.conns = 4.min(clients);
    fleet_cfg.connect = Some(ep);
    let fleet = std::thread::spawn(move || run_fleet(&fleet_cfg));

    let report = run_root_on(&listener, &root_cfg)?;
    fleet.join().expect("fleet thread")?;

    println!("{}", report.to_json(&root_cfg));
    let want = reference_consensus(
        root_cfg.seed,
        root_cfg.m,
        clients,
        root_cfg.participating,
        rounds,
    );
    assert_eq!(
        report.consensus, want,
        "socket-run consensus diverged from the in-process replay"
    );
    println!(
        "consensus over the socket == in-process reference, bit for bit \
         ({} sketches absorbed, {:.1} rounds/s)",
        report.absorbed, report.rounds_per_sec
    );
    Ok(())
}
