//! Heterogeneity sweep — how the personalization advantage scales with
//! non-i.i.d. severity (the paper's central claim: "a carefully designed
//! personalization strategy is the key to making extreme compression
//! viable").
//!
//! Sweeps the Dirichlet concentration α from near-pathological label skew
//! (α = 0.05) to near-i.i.d. (α = 100) and reports pFed1BS vs the best
//! one-bit global baseline (OBDA) and FedAvg. Expected shape: the one-bit
//! global method collapses as heterogeneity grows, pFed1BS does not.
//!
//! ```bash
//! cargo run --release --example heterogeneity_sweep [ROUNDS]
//! ```

use anyhow::Result;
use pfed1bs::config::RunConfig;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn main() -> Result<()> {
    pfed1bs::util::log::init_from_env();
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(25);

    let alphas = [0.05, 0.3, 1.0, 100.0];
    let algs = ["pfed1bs", "obda", "fedavg"];

    println!("{:<10} {:>10} {:>10} {:>10}", "alpha", "pfed1bs", "obda", "fedavg");
    let lab = Lab::new("artifacts")?;
    let mut rows = String::from("alpha,pfed1bs,obda,fedavg\n");
    for &alpha in &alphas {
        let mut accs = Vec::new();
        for alg in algs {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.algorithm = alg.to_string();
            cfg.partition = "dirichlet".into();
            cfg.dirichlet_alpha = alpha;
            cfg.rounds = rounds;
            cfg.eval_every = rounds.max(1) - 1;
            let r = lab.run(cfg)?;
            accs.push(r.final_accuracy);
        }
        println!(
            "{:<10} {:>9.2}% {:>9.2}% {:>9.2}%",
            alpha,
            100.0 * accs[0],
            100.0 * accs[1],
            100.0 * accs[2]
        );
        rows.push_str(&format!("{alpha},{:.6},{:.6},{:.6}\n", accs[0], accs[1], accs[2]));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/heterogeneity_sweep.csv", rows)?;
    println!("\nwritten: results/heterogeneity_sweep.csv");
    Ok(())
}
