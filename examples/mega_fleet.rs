//! Mega-fleet round: 100 000 synthetic clients streamed through 16 edge
//! aggregators into one root vote, in bounded memory (DESIGN.md §11).
//!
//! The point being demonstrated: the hierarchical server never holds
//! the cohort. Every client's m-bit sketch is generated, transported
//! (metered), absorbed into its edge's O(m) tally shard, and dropped —
//! peak payload residency is ONE sketch per edge walk, and the server
//! state is E shards × m tallies no matter how many clients stream
//! through. The edges then ship one `TallyFrame` each and this example
//! makes the root fold the DECODED frames (`absorb_frame`) — going one
//! step beyond the in-process engine, which meters the frames but
//! merges its in-memory shards — demonstrating that the wire format
//! alone carries everything the root needs, bit-identical to a flat
//! server absorbing all 100k uplinks (pinned in
//! `rust/tests/prop_topology.rs`).
//!
//! ```bash
//! cargo run --release --example mega_fleet [CLIENTS] [EDGES]
//! ```
//!
//! Needs no PJRT artifacts: the aggregation path is pure rust.

use anyhow::Result;
use pfed1bs::algorithms::{AggKind, ClientOutput, ClientStats, RoundAggregator, Uplink};
use pfed1bs::comm::{decode, encode, frame_bytes, Direction, Ledger, Payload};
use pfed1bs::sketch::bitpack::{SignVec, VoteAccumulator};
use pfed1bs::util::rng::Rng;

fn main() -> Result<()> {
    let clients: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let edges: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(16);
    let m = 10_177; // the paper's MNIST sketch dimension
    let p = 1.0f64 / clients as f64;

    println!("mega fleet: {clients} clients → {edges} edges → 1 root, m = {m} bits");
    let started = std::time::Instant::now();

    // E edge shards + a byte ledger — the ENTIRE server state
    let mut shards: Vec<RoundAggregator> = (0..edges)
        .map(|_| RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m))))
        .collect();
    let mut ledger = Ledger::new();

    // stream the cohort: each sketch exists only between generation and
    // absorb (payloads are consumed by `absorb` — nothing accumulates)
    for k in 0..clients {
        let mut rng = Rng::new(0xF1EE7 ^ k as u64);
        // a synthetic "client": biased signs so the vote is non-trivial
        let bias = (k % 97) as f32 / 97.0 * 0.2 + 0.4;
        let sketch = SignVec::from_fn(m, |_| rng.f32() < bias);
        let payload = Payload::Signs(sketch);
        ledger.record(Direction::Uplink, frame_bytes(&payload));
        let out = ClientOutput {
            client: k,
            uplink: Some(Uplink::new(0, payload)),
            state: None,
            stats: ClientStats { loss: 0.0 },
        };
        shards[k % edges].absorb(out, p as f32)?;
    }
    let absorbed: usize = shards.iter().map(|s| s.absorbed()).sum();

    // edge → root: one O(m) merge frame per edge, folded from the
    // DECODED wire bytes in canonical edge order
    let mut root = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)));
    for shard in &shards {
        let frame = shard.merge_payload().expect("vote shards always report");
        let bytes = encode(&frame);
        ledger.record_edge(Direction::Uplink, bytes.len());
        root.absorb_frame(decode(&bytes)?)?;
    }
    let round = ledger.end_round();

    let (AggKind::Vote(tally), _, delivered, _) = root.into_parts() else {
        unreachable!("root kind is fixed above")
    };
    let consensus = tally.finish();
    let plus = consensus.words().iter().map(|w| w.count_ones() as usize).sum::<usize>();

    println!("  absorbed         : {absorbed} uplinks across {edges} edge shards");
    println!("  root delivered   : {delivered} (via {edges} merge frames)");
    println!(
        "  uplink traffic   : {:.1} MiB over {} messages (client → edge)",
        round.uplink as f64 / (1024.0 * 1024.0),
        round.uplink_msgs
    );
    println!(
        "  edge tier        : {:.2} MiB over {} merge frames (edge → root)",
        round.edge_up as f64 / (1024.0 * 1024.0),
        round.edge_up_msgs
    );
    println!(
        "  resident state   : {} shards × {m} tallies (~{:.1} MiB) — independent of fleet size",
        edges,
        (edges * m * 16) as f64 / (1024.0 * 1024.0)
    );
    println!("  consensus        : {plus}/{m} bits voted +1");
    println!("  wall time        : {:.2} s", started.elapsed().as_secs_f64());
    Ok(())
}
